//! City-scale federation experiment (`repro --exp city`; ROADMAP
//! city-scale follow-up): 64–256 cells under per-district load, comparing
//! backhaul wirings and measuring what the hierarchical gossip
//! aggregation buys.
//!
//! The city is modelled as a cycle of four *districts* (downtown /
//! residential / industrial / stadium), assigned per cell round-robin:
//! districts differ in edge capacity and background load, so the weak
//! downtown cells overflow into their neighbours and the federation's
//! routing actually works for a living. The app registry is city-wide —
//! three apps every district's camera streams:
//!
//! - **district-cam** (open, priority 1, diurnal) — the day/night CCTV
//!   baseline; free to forward across the backhaul.
//! - **stadium-flash** (cell_local, priority 2, flash crowd) — a
//!   privacy-scoped burst that must *never* cross cells, whatever the
//!   load; the zero-violations line below is the acceptance proof.
//! - **iot-batch** (open, priority 0, Poisson) — background telemetry.
//!
//! (A truly per-district registry would give each district its own app
//! mix; apps here are global and districts differ through capacity and
//! load — the approximation keeps TaskId blocks and the recorder's
//! per-app accounting unchanged.)
//!
//! The sweep runs mesh/ring/tree at 64 cells and `hier:8` at 64/128/256.
//! Classic transitive gossip on a mesh costs O(cells²) summaries per
//! period (every edge relays every subject to every peer); the `hier`
//! shape groups cells into regions whose leaders exchange *damped
//! per-region aggregates*, cutting that toward O(cells·regions). The
//! per-cell gossip-byte lines at the end of the report are the measured
//! form of that claim, via the existing `gossip_bytes` metering.

use crate::config::{AppSpec, CellConfig, DeviceConfig, SystemConfig};
use crate::core::{NodeClass, PrivacyClass};
use crate::metrics::trace::SharedTrace;
use crate::net::FederationShape;
use crate::scheduler::PolicyKind;
use crate::sim::workload::ArrivalPattern;
use crate::sim::{RunReport, ScenarioBuilder};

use super::gossip::shape_hops;

/// Cells per region for the sweep's `hier` points.
pub const CITY_REGION_SIZE: u32 = 8;

/// The sweep: wiring shape × city size. Mesh/ring/tree stop at 64 cells
/// (a 256-cell mesh relays O(cells²) summaries per period — the cost the
/// hierarchy exists to avoid); `hier:8` scales to 256.
pub const CITY_SWEEP: [(FederationShape, usize); 6] = [
    (FederationShape::Mesh, 64),
    (FederationShape::Ring, 64),
    (FederationShape::Tree, 64),
    (FederationShape::Hier { region_size: CITY_REGION_SIZE }, 64),
    (FederationShape::Hier { region_size: CITY_REGION_SIZE }, 128),
    (FederationShape::Hier { region_size: CITY_REGION_SIZE }, 256),
];

/// Event-budget abort guard for one city run — orders of magnitude above
/// any sane sweep point, so it only fires on a runaway regression.
pub const CITY_MAX_EVENTS: u64 = 500_000_000;

/// One sweep cell's outcome.
#[derive(Debug, Clone)]
pub struct CityRow {
    /// Backhaul wiring shape.
    pub shape: FederationShape,
    /// City size (number of cells).
    pub n_cells: usize,
    /// Hop budget the shape was given ([`shape_hops`]).
    pub hops: u8,
    /// Frames that met their deadline.
    pub met: usize,
    /// Frames created.
    pub total: usize,
    /// Distinct frames placed across the backhaul.
    pub forwarded: usize,
    /// Privacy-scope violations (must stay 0 — `stadium-flash` is
    /// cell_local under flash-crowd overload).
    pub privacy_violations: usize,
    /// Total `EdgeSummary` bytes sent, all edges (gossip metering).
    pub gossip_bytes: u64,
    /// Candidate-snapshot full rebuilds across the run's pipelines.
    pub snapshot_rebuilds: u64,
    /// Candidate-snapshot cache reuses.
    pub snapshot_reuses: u64,
    /// Candidate-snapshot incremental delta applications.
    pub snapshot_deltas: u64,
    /// Warm-container pool hits.
    pub pool_hits: u64,
    /// Container cold starts (pool misses).
    pub pool_misses: u64,
    /// Engine events processed.
    pub events: u64,
    /// Wall-clock duration (ms).
    pub wall_ms: f64,
}

impl CityRow {
    /// Gossip bytes averaged over the city's cells — the sublinearity
    /// measure (a mesh grows linearly here, the hierarchy must not).
    pub fn gossip_bytes_per_cell(&self) -> u64 {
        self.gossip_bytes / self.n_cells as u64
    }
}

/// The city config at `n_cells` cells on `shape`. `n_images` scales the
/// diurnal stream; the flash and batch streams ride at half that count.
pub fn city_config(n_cells: usize, shape: FederationShape, n_images: u32) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Dds;
    // Districts, round-robin: downtown cells are deliberately too weak
    // for their offered load — their open frames must leave the cell.
    cfg.cells = (0..n_cells)
        .map(|c| match c % 4 {
            0 => CellConfig { warm_containers: 2, cpu_load_pct: 80.0 }, // downtown
            1 => CellConfig { warm_containers: 4, cpu_load_pct: 0.0 },  // residential
            2 => CellConfig { warm_containers: 6, cpu_load_pct: 0.0 },  // industrial
            _ => CellConfig { warm_containers: 4, cpu_load_pct: 10.0 }, // stadium
        })
        .collect();
    cfg.devices = (0..n_cells)
        .flat_map(|c| {
            (0..2).map(move |i| DeviceConfig {
                class: NodeClass::RaspberryPi,
                warm_containers: 2,
                camera: i == 0,
                // Downtown devices are as busy as their edge: the
                // district cannot absorb its own load, so its open
                // frames must cross the backhaul.
                cpu_load_pct: if c % 4 == 0 { 75.0 } else { 0.0 },
                location: (1.0 + i as f64, 0.0),
                battery: false,
                cell: c as u32,
            })
        })
        .collect();
    let batch = (n_images / 2).max(2);
    cfg.apps = vec![
        AppSpec {
            name: "district-cam".into(),
            deadline_ms: 2_000.0,
            privacy: PrivacyClass::Open,
            priority: 1,
            n_images,
            interval_ms: 400.0,
            size_kb: 29.0,
            side_px: 64,
            // One full day/night cycle across the stream.
            pattern: ArrivalPattern::Diurnal { period_ms: n_images as f64 * 400.0 },
            weight: None,
            admit_rate_per_s: None,
        },
        AppSpec {
            name: "stadium-flash".into(),
            deadline_ms: 1_500.0,
            privacy: PrivacyClass::CellLocal,
            priority: 2,
            n_images: batch,
            interval_ms: 300.0,
            size_kb: 29.0,
            side_px: 64,
            pattern: ArrivalPattern::FlashCrowd { mult: 10 },
            weight: None,
            admit_rate_per_s: None,
        },
        AppSpec {
            name: "iot-batch".into(),
            deadline_ms: 6_000.0,
            privacy: PrivacyClass::Open,
            priority: 0,
            n_images: batch,
            interval_ms: 900.0,
            size_kb: 29.0,
            side_px: 64,
            pattern: ArrivalPattern::Poisson,
            weight: None,
            admit_rate_per_s: None,
        },
    ];
    cfg.federation.topology = shape;
    cfg.federation.max_forward_hops = shape_hops(n_cells, shape);
    // City periods are slower than the gossip ablation's: at 256 cells
    // the summaries themselves are the bandwidth story.
    cfg.federation.gossip_period_ms = 500.0;
    cfg
}

/// Run one sweep cell.
pub fn city_run(shape: FederationShape, n_cells: usize, seed: u64, n_images: u32) -> CityRow {
    let cfg = city_config(n_cells, shape, n_images);
    let report = ScenarioBuilder::new(cfg)
        .seed(seed)
        .max_events(CITY_MAX_EVENTS)
        .run();
    CityRow {
        shape,
        n_cells,
        hops: shape_hops(n_cells, shape),
        met: report.summary.met,
        total: report.summary.total,
        forwarded: report.summary.forwarded,
        privacy_violations: report.summary.privacy_violations,
        gossip_bytes: report.summary.gossip_bytes.values().sum(),
        snapshot_rebuilds: report.summary.snapshot_rebuilds,
        snapshot_reuses: report.summary.snapshot_reuses,
        snapshot_deltas: report.summary.snapshot_deltas,
        pool_hits: report.summary.pool_hits,
        pool_misses: report.summary.pool_misses,
        events: report.events,
        wall_ms: report.wall_us as f64 / 1e3,
    }
}

/// One *observed* city run (`repro --exp city --trace/--timeline`): the
/// `hier` shape at `cells` with the observability knobs attached, so the
/// flash-crowd dip and recovery can be plotted over time. Separate from
/// the sweep so [`city`] itself stays knob-free (and byte-identical).
pub fn city_observed(
    seed: u64,
    n_images: u32,
    cells: usize,
    trace: Option<SharedTrace>,
    timeline_window_ms: Option<f64>,
) -> RunReport {
    let cells = cells.clamp(2, 256);
    let shape = FederationShape::Hier { region_size: CITY_REGION_SIZE };
    let cfg = city_config(cells, shape, n_images);
    let mut b = ScenarioBuilder::new(cfg).seed(seed).max_events(CITY_MAX_EVENTS);
    if let Some(t) = trace {
        b = b.trace(t);
    }
    if let Some(w) = timeline_window_ms {
        b = b.timeline(w);
    }
    b.run()
}

/// The full sweep, capped at `max_cells` (the CI smoke step shrinks the
/// city; duplicate post-clamp points collapse to one run).
pub fn city(seed: u64, n_images: u32, max_cells: usize) -> Vec<CityRow> {
    city_jobs(seed, n_images, max_cells, 1)
}

/// [`city`] over `jobs` worker threads. Point enumeration stays
/// sequential (it is the ordering contract); only the runs fan out, and
/// rows come back in enumeration order — `jobs = 1` is the classic loop.
pub fn city_jobs(seed: u64, n_images: u32, max_cells: usize, jobs: usize) -> Vec<CityRow> {
    let mut points: Vec<(FederationShape, usize)> = Vec::new();
    for (shape, cells) in CITY_SWEEP {
        let cells = cells.min(max_cells).max(2);
        if !points.contains(&(shape, cells)) {
            points.push((shape, cells));
        }
    }
    super::run_indexed(jobs, points, |(shape, cells)| city_run(shape, cells, seed, n_images))
}

/// Render the sweep plus the gossip-sublinearity and privacy lines the
/// CI smoke step greps for.
pub fn render_city(rows: &[CityRow]) -> String {
    let mut out = String::from(
        "## City-scale federation: per-district load, 64-256 cells, hierarchical gossip\n",
    );
    out.push_str(&format!(
        "{:>6} {:>6} {:>5} {:>8} {:>8} {:>10} {:>10} {:>8} {:>14} {:>10} {:>10} {:>9}\n",
        "shape",
        "cells",
        "hops",
        "met",
        "total",
        "forwarded",
        "gossip_kb",
        "B/cell",
        "snap(r/u/d)",
        "pool(h/m)",
        "events",
        "wall_ms"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:>6} {:>6} {:>5} {:>8} {:>8} {:>10} {:>10} {:>8} {:>14} {:>10} {:>10} {:>9.1}\n",
            r.shape.as_str(),
            r.n_cells,
            r.hops,
            r.met,
            r.total,
            r.forwarded,
            r.gossip_bytes / 1024,
            r.gossip_bytes_per_cell(),
            format!("{}/{}/{}", r.snapshot_rebuilds, r.snapshot_reuses, r.snapshot_deltas),
            format!("{}/{}", r.pool_hits, r.pool_misses),
            r.events,
            r.wall_ms,
        ));
    }
    // The aggregation claim, measured: hier vs mesh at the same size...
    let mesh = rows.iter().filter(|r| r.shape == FederationShape::Mesh).max_by_key(|r| r.n_cells);
    let hier_at = |n: usize| {
        rows.iter()
            .find(|r| matches!(r.shape, FederationShape::Hier { .. }) && r.n_cells == n)
    };
    if let Some(m) = mesh {
        if let Some(h) = hier_at(m.n_cells) {
            let (mb, hb) = (m.gossip_bytes_per_cell().max(1), h.gossip_bytes_per_cell());
            out.push_str(&format!(
                "City gossip bytes/cell at {} cells: mesh {} vs hier {} ({}% of mesh)\n",
                m.n_cells,
                mb,
                hb,
                hb * 100 / mb
            ));
        }
    }
    // ...and how the hierarchy's per-cell cost grows with the city.
    let growth: Vec<String> = rows
        .iter()
        .filter(|r| matches!(r.shape, FederationShape::Hier { .. }))
        .map(|r| format!("{}@{}", r.gossip_bytes_per_cell(), r.n_cells))
        .collect();
    if !growth.is_empty() {
        out.push_str(&format!("Hier gossip bytes/cell growth: {}\n", growth.join(" -> ")));
    }
    let violations: usize = rows.iter().map(|r| r.privacy_violations).sum();
    let forwarded: usize = rows.iter().map(|r| r.forwarded).sum();
    out.push_str(&format!("City privacy violations (all runs): {violations}\n"));
    out.push_str(&format!("City forwarded frames (all runs): {forwarded}\n"));
    // Pipeline-cache and container-pool economics across the sweep — the
    // perf counters the dashboards track (ROADMAP PR-4 follow-up).
    let (snap_r, snap_u, snap_d) = rows.iter().fold((0, 0, 0), |acc, r| {
        (acc.0 + r.snapshot_rebuilds, acc.1 + r.snapshot_reuses, acc.2 + r.snapshot_deltas)
    });
    out.push_str(&format!(
        "City snapshot maintenance (all runs): {snap_r} rebuilds / {snap_u} reuses / {snap_d} deltas\n"
    ));
    let hits: u64 = rows.iter().map(|r| r.pool_hits).sum();
    let misses: u64 = rows.iter().map(|r| r.pool_misses).sum();
    out.push_str(&format!(
        "City container pool (all runs): {hits} warm hits / {misses} cold starts\n"
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn city_configs_validate_across_the_sweep() {
        for (shape, cells) in CITY_SWEEP {
            let c = city_config(cells, shape, 24);
            c.validate().unwrap();
            assert_eq!(c.n_cells(), cells);
            assert_eq!(c.federation.topology, shape);
            assert_eq!(c.federation.max_forward_hops, shape_hops(cells, shape));
            assert_eq!(c.apps.len(), 3);
            // Every cell streams (each has a camera device).
            assert_eq!(c.devices.iter().filter(|d| d.camera).count(), cells);
        }
    }

    #[test]
    fn small_city_meets_accounting_and_privacy() {
        // An 8-cell hier city: every frame accounted, the cell_local
        // flash app never leaks, the weak downtown cells actually push
        // open frames across the backhaul.
        let r = city_run(FederationShape::Hier { region_size: 4 }, 8, 7, 8);
        // 8 cameras × (8 diurnal + 4 flash + 4 batch) frames.
        assert_eq!(r.total, 8 * 16);
        assert_eq!(r.privacy_violations, 0);
        assert!(r.met > 0);
        assert!(r.forwarded > 0, "downtown overload must cross the backhaul");
        assert!(r.gossip_bytes > 0);
    }

    #[test]
    fn hier_gossip_is_cheaper_than_mesh_at_equal_size() {
        // The aggregation claim at test scale: same city, same load, same
        // period — region-aggregated gossip moves fewer bytes than full
        // mesh relaying.
        let mesh = city_run(FederationShape::Mesh, 8, 7, 8);
        let hier = city_run(FederationShape::Hier { region_size: 4 }, 8, 7, 8);
        assert!(
            hier.gossip_bytes < mesh.gossip_bytes,
            "hier {} must undercut mesh {}",
            hier.gossip_bytes,
            mesh.gossip_bytes
        );
        assert_eq!(mesh.privacy_violations + hier.privacy_violations, 0);
    }

    #[test]
    fn render_has_grid_and_acceptance_lines() {
        let rows = city(7, 6, 8);
        let s = render_city(&rows);
        assert!(s.contains("shape"));
        assert!(s.contains("snap(r/u/d)"));
        assert!(s.contains("pool(h/m)"));
        assert!(s.contains("Hier gossip bytes/cell growth:"));
        assert!(s.contains("City privacy violations (all runs): 0"));
        assert!(s.contains("City forwarded frames (all runs):"));
        assert!(s.contains("City snapshot maintenance (all runs):"));
        assert!(s.contains("City container pool (all runs):"));
    }

    #[test]
    fn observed_city_run_traces_and_samples() {
        use crate::metrics::trace::{shared, JsonlTrace, SharedBuf};
        let buf = SharedBuf::new();
        let sink = shared(JsonlTrace::new(Box::new(buf.clone())));
        let r = city_observed(7, 8, 4, Some(sink), Some(1_000.0));
        let tl = r.timeline.expect("timeline was enabled");
        assert!(!tl.rows().is_empty());
        let text = String::from_utf8(buf.contents()).unwrap();
        assert!(text.contains(r#""kind":"place""#));
        assert!(text.contains(r#""kind":"gossip_send""#));
        // Knob-free sweep results are untouched by an observed run having
        // happened (the knobs live on a separate builder).
        assert_eq!(r.summary.total, 4 * 16);
    }
}
