//! Federation experiment (beyond the paper): deadline satisfaction of a
//! 1-, 2- and 4-cell federation under the Fig. 8 edge-stress schedule.
//!
//! Methodology mirrors Fig. 8: 1000 images at 50 ms from cell 0's camera,
//! 5 s constraint, the *stressed* edge (cell 0) swept over the Fig. 8
//! background-load levels. Extra cells contribute no workload of their
//! own — they are idle capacity reachable only over the backhaul, so any
//! gain is pure edge↔edge federation (DDS `ToPeerEdge` forwarding).

use crate::config::{CellConfig, DeviceConfig, SystemConfig, WorkloadConfig};
use crate::core::NodeClass;
use crate::scheduler::PolicyKind;
use crate::sim::workload::ArrivalPattern;
use crate::sim::ScenarioBuilder;

pub use super::figures::FIG8_LOADS;

/// Cell counts compared by the experiment.
pub const FED_CELLS: [usize; 3] = [1, 2, 4];

/// One (cell count, edge load) cell of the sweep.
#[derive(Debug, Clone)]
pub struct FedRow {
    /// Number of federation cells.
    pub n_cells: usize,
    /// Background CPU load on the stressed (cell 0) edge.
    pub edge_load_pct: f64,
    /// Frames that met their deadline.
    pub met: usize,
    /// Images DDS forwarded across cells (always 0 when `n_cells == 1`).
    pub forwarded: usize,
}

/// A federation of `n_cells` identical cells: each edge has 4 warm
/// containers and two Raspberry Pis; only cell 0's first device has the
/// camera (and therefore all the load).
pub fn fed_config(n_cells: usize) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Dds;
    cfg.cells = vec![CellConfig { warm_containers: 4, cpu_load_pct: 0.0 }; n_cells];
    cfg.devices = (0..n_cells)
        .flat_map(|c| {
            (0..2).map(move |i| DeviceConfig {
                class: NodeClass::RaspberryPi,
                warm_containers: 2,
                camera: c == 0 && i == 0,
                cpu_load_pct: 0.0,
                location: (1.0 + i as f64, 0.0),
                battery: false,
                cell: c as u32,
            })
        })
        .collect();
    cfg
}

fn fed_workload(n_images: u32, deadline_ms: f64) -> WorkloadConfig {
    WorkloadConfig {
        n_images,
        interval_ms: 50.0,
        size_kb: 29.0,
        size_jitter_kb: 0.0,
        deadline_ms,
        side_px: 64,
        pattern: ArrivalPattern::Uniform,
    }
}

/// Run one sweep cell.
pub fn fed_run(n_cells: usize, load: f64, seed: u64, n_images: u32, deadline_ms: f64) -> FedRow {
    let report = ScenarioBuilder::new(fed_config(n_cells))
        .workload(fed_workload(n_images, deadline_ms))
        .edge_load(load)
        .seed(seed)
        .run();
    FedRow {
        n_cells,
        edge_load_pct: load,
        met: report.summary.met,
        forwarded: report.summary.forwarded,
    }
}

/// The full sweep: cell counts × Fig. 8 load levels.
pub fn fed(seed: u64) -> Vec<FedRow> {
    fed_jobs(seed, 1)
}

/// [`fed`] over `jobs` worker threads; rows return in the sequential
/// sweep's enumeration order (`jobs = 1` is the classic loop).
pub fn fed_jobs(seed: u64, jobs: usize) -> Vec<FedRow> {
    let mut points = Vec::new();
    for &n_cells in &FED_CELLS {
        for &load in &FIG8_LOADS {
            points.push((n_cells, load));
        }
    }
    super::run_indexed(jobs, points, |(n_cells, load)| fed_run(n_cells, load, seed, 1_000, 5_000.0))
}

/// Render the sweep as an aligned text grid (one line per load level,
/// met/forwarded per cell count).
pub fn render_fed(rows: &[FedRow]) -> String {
    let mut out = String::from(
        "## Federation: DDS met count vs cells under edge stress (1000 imgs @50ms, 5 s)\n",
    );
    out.push_str(&format!(
        "{:>8} {:>12} {:>12} {:>12} {:>10}\n",
        "load%", "1 cell", "2 cells", "4 cells", "fwd(4)"
    ));
    for &load in &FIG8_LOADS {
        let get = |n: usize| {
            rows.iter()
                .find(|r| r.n_cells == n && r.edge_load_pct == load)
                .map(|r| (r.met, r.forwarded))
                .unwrap_or((0, 0))
        };
        let (m1, _) = get(1);
        let (m2, _) = get(2);
        let (m4, f4) = get(4);
        out.push_str(&format!(
            "{:>8} {:>12} {:>12} {:>12} {:>10}\n",
            load, m1, m2, m4, f4
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_cell_never_forwards() {
        let r = fed_run(1, 100.0, 7, 120, 2_000.0);
        assert_eq!(r.forwarded, 0);
        assert_eq!(r.n_cells, 1);
    }

    #[test]
    fn federation_forwards_and_helps_under_stress() {
        // Acceptance: a loaded 2-cell federation must actually use the
        // backhaul and must not do worse than the lone cell.
        let solo = fed_run(1, 100.0, 7, 300, 2_000.0);
        let fed2 = fed_run(2, 100.0, 7, 300, 2_000.0);
        assert!(fed2.forwarded > 0, "expected cross-cell forwards, got 0");
        assert!(
            fed2.met >= solo.met,
            "2 cells ({}) must not trail 1 cell ({})",
            fed2.met,
            solo.met
        );
    }

    #[test]
    fn fed_config_shape() {
        let c = fed_config(4);
        c.validate().unwrap();
        assert_eq!(c.n_cells(), 4);
        assert_eq!(c.devices.len(), 8);
        assert_eq!(c.devices.iter().filter(|d| d.camera).count(), 1);
        assert!(c.devices[0].camera && c.devices[0].cell == 0);
    }
}
