//! Experiment harness: one generator per table/figure in the paper's
//! evaluation (§IV–§V). The `benches/` targets and the `repro` CLI
//! subcommand both call these — a single source of truth for what each
//! experiment means.
//!
//! Every generator returns structured rows plus the paper's reference
//! numbers so reports can print paper-vs-measured side by side.

pub mod churn;
pub mod city;
pub mod federation;
pub mod figures;
pub mod gossip;
pub mod overload;
pub mod parallel;
pub mod slo;
pub mod tables;
pub mod tier;

pub use churn::{
    apply_scenario, churn, churn_config, churn_jobs, churn_run, churnsweep, churnsweep_jobs,
    churnsweep_run, render_churn, render_churnsweep, ChurnRow, ChurnScenario, ChurnSweepRow,
    SWEEP_MTBF_MS,
};
pub use city::{
    city, city_config, city_jobs, city_observed, city_run, render_city, CityRow, CITY_MAX_EVENTS,
    CITY_REGION_SIZE, CITY_SWEEP,
};
pub use federation::{fed, fed_config, fed_jobs, fed_run, render_fed, FedRow};
pub use gossip::{
    gossip, gossip_config, gossip_jobs, gossip_run, render_gossip, shape_hops, GossipRow,
    GOSSIP_BACKHAUL_MBPS, GOSSIP_CELLS, GOSSIP_PERIODS_MS, GOSSIP_SHAPES,
};
pub use overload::{
    overload, overload_config, overload_jobs, overload_run, render_overload, OverloadMode,
    OverloadRow, OVERLOAD_MODES, OVERLOAD_MULTS,
};
pub use parallel::{default_jobs, run_indexed};
pub use slo::{render_slo, slo, slo_config, slo_jobs, slo_run, SloRow, SLO_CELLS};
pub use tier::{
    render_tier, tier, tier_config, tier_jobs, tier_run, TierRow, TIER_CELLS, TIER_MULTS,
    TIER_UPLINKS_MS,
};
pub use figures::{fig5, fig6, fig7, fig8, Fig5Row, Fig7Row, Fig8Row};
pub use tables::{table2, table3, table4, table5, table6, TableRow};

/// A paper-vs-measured comparison row.
#[derive(Debug, Clone, PartialEq)]
pub struct Comparison {
    /// The x-axis value (constraint, load level, …).
    pub x: f64,
    /// The paper’s reference number.
    pub paper: f64,
    /// Our measured number.
    pub measured: f64,
}

impl Comparison {
    /// Relative error of measured vs. paper (0 when the paper reads 0).
    pub fn rel_err(&self) -> f64 {
        if self.paper == 0.0 {
            0.0
        } else {
            (self.measured - self.paper).abs() / self.paper
        }
    }
}

/// Render comparisons as an aligned text table.
pub fn render_comparisons(title: &str, x_label: &str, rows: &[Comparison]) -> String {
    let mut out = format!("## {title}\n{:>12} {:>14} {:>14} {:>8}\n", x_label, "paper", "measured", "err%");
    for r in rows {
        out.push_str(&format!(
            "{:>12} {:>14.1} {:>14.1} {:>7.1}%\n",
            r.x,
            r.paper,
            r.measured,
            r.rel_err() * 100.0
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_err_basics() {
        let c = Comparison { x: 1.0, paper: 100.0, measured: 110.0 };
        assert!((c.rel_err() - 0.1).abs() < 1e-12);
        let z = Comparison { x: 1.0, paper: 0.0, measured: 5.0 };
        assert_eq!(z.rel_err(), 0.0);
    }

    #[test]
    fn render_contains_rows() {
        let rows = vec![Comparison { x: 2.0, paper: 10.0, measured: 12.0 }];
        let s = render_comparisons("T", "n", &rows);
        assert!(s.contains("## T"));
        assert!(s.contains("20.0%"));
    }
}
