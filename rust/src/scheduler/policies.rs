//! Policy implementations: DDS (§V.B.3 of the paper) and the comparison
//! groups AOR / AOE / EODS, plus ablations.
//!
//! Policies are the **Place** stage of the staged scheduling pipeline
//! (DESIGN.md §3): the edge-level decision consumes the Filter stage's
//! [`CandidateSnapshot`](super::CandidateSnapshot) — MP and peer tables
//! resolved once per decision — instead of re-scanning raw tables.

use crate::core::{NodeClass, NodeId, Placement, PrivacyClass};
use crate::profile::PredictInput;
use crate::util::SplitMix64;

use super::{DeviceCtx, EdgeCtx, SchedulerPolicy};

// ---------------------------------------------------------------------
// Pinned-constraint handling shared by all policies: a task pinned to a
// node (privacy/trust constraint, §II "Task and Trust Constraints") is
// routed there unconditionally.
// ---------------------------------------------------------------------

fn pinned_device(ctx: &DeviceCtx) -> Option<Placement> {
    let pin = ctx.img.constraint.pinned_node?;
    Some(if pin == ctx.local.node { Placement::Local } else { Placement::ToEdge })
}

fn pinned_edge(ctx: &EdgeCtx) -> Option<Placement> {
    let pin = ctx.img.constraint.pinned_node?;
    Some(if pin == ctx.edge.node { Placement::Local } else { Placement::Offload(pin) })
}

// ---------------------------------------------------------------------
// Federation-level fallback shared by the DDS family (DESIGN.md
// §Federation, §Hierarchical routing): when this cell is exhausted — no
// feasible device candidate was found *and* the edge pool has no idle
// container — consider shedding the image over the backhaul. Candidates
// include multi-hop subjects learned through transitive gossip; scoring is
// load- and weight-aware: (advertised queue depth ÷ app weight, hop
// distance, predicted backhaul+execution time) instead of first-feasible.
// Baselines never call this.
// ---------------------------------------------------------------------

fn peer_fallback(ctx: &EdgeCtx) -> Option<Placement> {
    // Privacy hard filter (DESIGN.md §Constraints & QoS): only `open`
    // frames may cross the backhaul — `cell_local` and `device_local`
    // scopes end at the cell boundary, so peers are not candidates. This
    // clamp holds on every intermediate hop: a forwarded frame re-enters
    // this function at each cell it traverses.
    if ctx.img.constraint.privacy != PrivacyClass::Open {
        return None;
    }
    // Hop budget spent: the frame travels no further (legacy Forward
    // frames decode to ttl 0, reproducing the classic no-re-forward rule).
    if ctx.hops_left == 0 {
        return None;
    }
    // The cell counts as exhausted only when the edge's own pool is full;
    // otherwise the local pool is still the cheaper choice.
    if ctx.edge.busy_containers < ctx.edge.warm_containers {
        return None;
    }
    let budget = ctx.remaining_ms();
    let weight = ctx.app_weight.max(1) as f64;
    let edge_pred = ctx.predictors.for_class(NodeClass::EdgeServer);
    // Score: weighted queue depth first (load-awareness ÷ the app's
    // weighted-fair share), then hop distance, then the predicted
    // transfer+execution time, then NodeId (exact-tie determinism).
    let mut best: Option<(f64, u8, f64, NodeId)> = None;
    for peer in ctx.candidates.peers() {
        // Only fresh gossip is trusted, and suspected-down peers are never
        // forwarding targets even inside the staleness window (DESIGN.md
        // §Churn) — both resolved by the snapshot. Relayed entries carry
        // the *subject's* timestamp, so transitive knowledge ages (and is
        // distrusted) exactly like direct knowledge.
        if !peer.fresh || peer.suspect {
            continue;
        }
        // Loop protection: neither a visited subject nor a next hop that
        // would bounce the frame back is a candidate.
        if ctx.visited.contains(&peer.state.edge) || ctx.visited.contains(&peer.state.via) {
            continue;
        }
        // Reaching a subject `hops` relays away takes `hops + 1` sends.
        if peer.state.hops as u16 + 1 > ctx.hops_left as u16 {
            continue;
        }
        // The peer must advertise spare capacity somewhere in its cell
        // (own pool or its devices) — the availability check, one level
        // up. Relayed copies arrive pre-damped (DESIGN.md §Hierarchical
        // routing), so distant slack is already discounted here.
        if peer.state.cell_idle_containers() == 0 {
            continue;
        }
        // Predict backhaul transfer + peer-pool execution from the
        // gossiped summary (the peer may still offload within its cell,
        // which only improves on this estimate). Every extra relay hop
        // pays one more backhaul transfer, approximated with the
        // next-hop link.
        let inp = PredictInput {
            size_kb: ctx.img.size_kb,
            link: Some(peer.link),
            busy_containers: peer.state.busy_containers,
            warm_containers: peer.state.warm_containers.max(1),
            queued_images: peer.state.queued_images,
            cpu_load_pct: peer.state.cpu_load_pct,
        };
        let t = edge_pred.predict_total_ms(&inp)
            + peer.state.hops as f64 * peer.link.transfer_ms(ctx.img.size_kb);
        if t > budget {
            continue;
        }
        let qd = peer.state.queued_images as f64 / weight;
        let key = (qd, peer.state.hops, t, peer.state.edge);
        let better = match best {
            None => true,
            Some(b) => key < b,
        };
        if better {
            best = Some(key);
        }
    }
    best.map(|(_, _, _, e)| Placement::ToPeerEdge(e))
}

// ---------------------------------------------------------------------
// Tier-level fallback shared by the DDS family (DESIGN.md §4e): when the
// whole federation is exhausted — no device candidate, no feasible peer —
// consider shipping the frame up the WAN uplink to the elastic cloud.
// Last resort by construction (it runs after `peer_fallback` declined)
// because the uplink's latency dwarfs the backhaul's; the cloud's
// unbounded capacity is only worth that toll when the frame would
// otherwise queue past its deadline. Baselines never call this.
// ---------------------------------------------------------------------

fn cloud_fallback(ctx: &EdgeCtx) -> Option<Placement> {
    // Privacy hard filter (DESIGN.md §Constraints & QoS): only `open`
    // frames may traverse the uplink. `clamp_placement` backstops this on
    // every dispatch path; deciding it here too keeps the policy honest.
    if ctx.img.constraint.privacy != PrivacyClass::Open {
        return None;
    }
    let cc = ctx.cloud?;
    // Same exhaustion rule as the federation level: while the edge pool
    // has an idle container, local is the cheaper choice.
    if ctx.edge.busy_containers < ctx.edge.warm_containers {
        return None;
    }
    // Predict uplink transfer + cloud execution. The cloud never queues
    // (elastic capacity): no busy containers, no backlog, a bare pool.
    let inp = PredictInput {
        size_kb: ctx.img.size_kb,
        link: Some(cc.uplink),
        busy_containers: 0,
        warm_containers: 1,
        queued_images: 0,
        cpu_load_pct: 0.0,
    };
    let t = ctx.predictors.for_class(NodeClass::CloudServer).predict_total_ms(&inp);
    (t <= ctx.remaining_ms()).then_some(Placement::ToCloud(cc.node))
}

// ---------------------------------------------------------------------
// AOR — All On the Raspberry Pi (comparison group 1).
// ---------------------------------------------------------------------

/// Never uses the edge server: every image is processed at its origin.
pub struct Aor;

impl SchedulerPolicy for Aor {
    fn name(&self) -> &'static str {
        "aor"
    }

    fn decide_device(&mut self, _ctx: &DeviceCtx) -> Placement {
        Placement::Local
    }

    fn decide_edge(&mut self, _ctx: &EdgeCtx) -> Placement {
        // AOR tasks never reach the edge; if one does (pinned elsewhere),
        // run it in the edge pool.
        Placement::Local
    }
}

// ---------------------------------------------------------------------
// AOE — All On the Edge server (comparison group 2).
// ---------------------------------------------------------------------

/// Every image is transmitted to and processed on the edge server.
pub struct Aoe;

impl SchedulerPolicy for Aoe {
    fn name(&self) -> &'static str {
        "aoe"
    }

    fn decide_device(&mut self, ctx: &DeviceCtx) -> Placement {
        pinned_device(ctx).unwrap_or(Placement::ToEdge)
    }

    fn decide_edge(&mut self, ctx: &EdgeCtx) -> Placement {
        pinned_edge(ctx).unwrap_or(Placement::Local)
    }
}

// ---------------------------------------------------------------------
// EODS — Even-Odd Distributed Scheduling (comparison group 3).
// ---------------------------------------------------------------------

/// Static split: odd sequence numbers stay on the device, even ones go to
/// the edge server ("the Raspberry Pi was responsible for processing
/// images with odd-numbered sequences").
pub struct Eods;

impl SchedulerPolicy for Eods {
    fn name(&self) -> &'static str {
        "eods"
    }

    fn decide_device(&mut self, ctx: &DeviceCtx) -> Placement {
        if let Some(p) = pinned_device(ctx) {
            return p;
        }
        if ctx.img.seq % 2 == 1 {
            Placement::Local
        } else {
            Placement::ToEdge
        }
    }

    fn decide_edge(&mut self, ctx: &EdgeCtx) -> Placement {
        pinned_edge(ctx).unwrap_or(Placement::Local)
    }
}

// ---------------------------------------------------------------------
// DDS — the paper's Dynamic Distributed Scheduler.
// ---------------------------------------------------------------------

/// The paper's two-level dynamic policy:
///
/// 1. **Device level** (local-first, §III-A): predict the end-to-end local
///    time from the profile model; if it fits the remaining deadline
///    budget, keep the task local, otherwise forward it to the edge.
/// 2. **Edge level** (§V.B.3): prefer offloading to an end device that
///    (a) predicts in-budget *and* (b) reports an idle warm container —
///    the availability check that compensates for decision-to-execution
///    staleness ("only offloads the task to that device if containers are
///    available"). Otherwise run in the edge pool.
pub struct Dds {
    /// Whether the availability check is enforced (disabled by the
    /// `DdsNoAvail` ablation).
    require_idle: bool,
}

impl Dds {
    /// The paper’s DDS with the availability check enabled.
    pub fn new() -> Self {
        Dds { require_idle: true }
    }
}

impl Default for Dds {
    fn default() -> Self {
        Self::new()
    }
}

impl SchedulerPolicy for Dds {
    fn name(&self) -> &'static str {
        if self.require_idle {
            "dds"
        } else {
            "dds-no-avail"
        }
    }

    fn decide_device(&mut self, ctx: &DeviceCtx) -> Placement {
        if let Some(p) = pinned_device(ctx) {
            return p;
        }
        // Privacy hard filter: a device-local frame never leaves its
        // origin, whatever the prediction says (the node layer enforces
        // this for every policy; DDS also decides it natively).
        if ctx.img.constraint.privacy == PrivacyClass::DeviceLocal {
            return Placement::Local;
        }
        // Churn fallback (DESIGN.md §Churn): a suspected-dead edge server
        // would swallow the frame — a late local result beats a lost one.
        if ctx.edge_suspected {
            return Placement::Local;
        }
        let inp = PredictInput {
            size_kb: ctx.img.size_kb,
            link: None,
            busy_containers: ctx.local.busy_containers,
            warm_containers: ctx.local.warm_containers,
            queued_images: ctx.local.queued_images,
            cpu_load_pct: ctx.local.cpu_load_pct,
        };
        let predicted = ctx.predictor.predict_total_ms(&inp);
        if predicted <= ctx.remaining_ms() {
            Placement::Local
        } else {
            Placement::ToEdge
        }
    }

    fn decide_edge(&mut self, ctx: &EdgeCtx) -> Placement {
        if let Some(p) = pinned_edge(ctx) {
            return p;
        }
        let budget = ctx.remaining_ms();

        // Candidate end devices, by predicted total time; only fresh
        // profiles are trusted (the origin, suspicion, and link filters
        // are already resolved into the snapshot). The ranking is
        // EDF-flavoured (DESIGN.md §Constraints & QoS): feasibility is
        // predicted-completion vs the frame's deadline, the winner is the
        // candidate finishing with the most slack left (= minimum
        // predicted completion), and exact prediction ties break
        // deterministically by NodeId rather than by table-registration
        // order (which churn rejoins can permute).
        let mut best: Option<(f64, crate::core::NodeId)> = None;
        for c in ctx.candidates.devices() {
            if !c.fresh || c.suspect {
                continue;
            }
            if self.require_idle && c.state.idle_containers() == 0 {
                continue;
            }
            let predictor = ctx.predictors.for_class(c.state.class);
            let inp = PredictInput::from_state(&c.state, ctx.img.size_kb, Some(c.link));
            let t = predictor.predict_total_ms(&inp);
            let better = t <= budget
                && best.map_or(true, |(bt, bn)| t < bt || (t == bt && c.state.node < bn));
            if better {
                best = Some((t, c.state.node));
            }
        }
        if let Some((_, node)) = best {
            return Placement::Offload(node);
        }
        // Federation level: pool and devices exhausted → try a peer cell.
        if let Some(p) = peer_fallback(ctx) {
            return p;
        }
        // Tier level (DESIGN.md §4e): the whole federation declined —
        // the elastic cloud is the last resort before queueing locally.
        if let Some(p) = cloud_fallback(ctx) {
            return p;
        }
        Placement::Local
    }

    fn churn_aware(&self) -> bool {
        true
    }
}

/// Ablation: DDS without the idle-container availability check — measures
/// how much the paper's staleness compensation matters (DESIGN.md
/// ablations).
pub struct DdsNoAvail(Dds);

impl DdsNoAvail {
    /// DDS without the idle-container availability check.
    pub fn new() -> Self {
        DdsNoAvail(Dds { require_idle: false })
    }
}

impl Default for DdsNoAvail {
    fn default() -> Self {
        Self::new()
    }
}

impl SchedulerPolicy for DdsNoAvail {
    fn name(&self) -> &'static str {
        "dds-no-avail"
    }

    fn decide_device(&mut self, ctx: &DeviceCtx) -> Placement {
        self.0.decide_device(ctx)
    }

    fn decide_edge(&mut self, ctx: &EdgeCtx) -> Placement {
        self.0.decide_edge(ctx)
    }

    fn churn_aware(&self) -> bool {
        true
    }
}

/// Extension policy (the paper's §VI future work): DDS with battery
/// awareness.
///
/// Device level: a battery-powered device below its reserve threshold
/// conserves energy — it forwards frames to the edge even when the time
/// prediction fits (compute costs ~1 mWh/image; radios are far cheaper).
/// Edge level: candidates below the reserve are skipped, and among
/// feasible candidates mains-powered nodes win; battery-powered ties break
/// toward the fuller battery, then the faster prediction.
pub struct DdsEnergy {
    inner: Dds,
    reserve_pct: f64,
}

impl DdsEnergy {
    /// Battery-aware DDS conserving below `reserve_pct` percent.
    pub fn new(reserve_pct: f64) -> Self {
        DdsEnergy { inner: Dds::new(), reserve_pct }
    }
}

impl SchedulerPolicy for DdsEnergy {
    fn name(&self) -> &'static str {
        "dds-energy"
    }

    fn decide_device(&mut self, ctx: &DeviceCtx) -> Placement {
        if let Some(p) = pinned_device(ctx) {
            return p;
        }
        // Privacy beats battery conservation: a device-local frame stays
        // put even on a low-reserve device.
        if ctx.img.constraint.privacy == PrivacyClass::DeviceLocal {
            return Placement::Local;
        }
        // Even a battery-conserving device keeps frames local when the
        // edge is suspected down — forwarding would just lose them.
        if ctx.edge_suspected {
            return Placement::Local;
        }
        if let Some(batt) = ctx.local.battery_pct {
            if batt < self.reserve_pct {
                return Placement::ToEdge;
            }
        }
        self.inner.decide_device(ctx)
    }

    fn decide_edge(&mut self, ctx: &EdgeCtx) -> Placement {
        if let Some(p) = pinned_edge(ctx) {
            return p;
        }
        let budget = ctx.remaining_ms();
        // Score: (battery class, battery level, predicted time). Mains
        // (None) sorts best via the 200.0 sentinel > any real percent.
        let mut best: Option<(f64, f64, crate::core::NodeId)> = None;
        for c in ctx.candidates.devices() {
            if !c.fresh || c.suspect {
                continue;
            }
            if c.state.idle_containers() == 0 {
                continue;
            }
            let batt = c.state.battery_pct.unwrap_or(200.0);
            if batt < self.reserve_pct {
                continue; // preserve low-battery devices
            }
            let predictor = ctx.predictors.for_class(c.state.class);
            let inp = PredictInput::from_state(&c.state, ctx.img.size_kb, Some(c.link));
            let t = predictor.predict_total_ms(&inp);
            if t > budget {
                continue;
            }
            let better = match best {
                None => true,
                Some((bb, bt, _)) => batt > bb || (batt == bb && t < bt),
            };
            if better {
                best = Some((batt, t, c.state.node));
            }
        }
        if let Some((_, _, node)) = best {
            return Placement::Offload(node);
        }
        // Peer edges are mains-powered infrastructure: shedding to a peer
        // cell never costs device battery, so the energy policy federates
        // under the same exhaustion rule as plain DDS.
        if let Some(p) = peer_fallback(ctx) {
            return p;
        }
        // The cloud is mains-powered too — same tier-level last resort.
        if let Some(p) = cloud_fallback(ctx) {
            return p;
        }
        Placement::Local
    }

    fn churn_aware(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------
// Profile-blind ablation baselines.
// ---------------------------------------------------------------------

/// Alternates local/edge at the device, and round-robins offload targets
/// (including the edge itself) at the edge — dynamic but profile-blind.
#[derive(Default)]
pub struct RoundRobin {
    device_flip: bool,
    edge_idx: usize,
}

impl SchedulerPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn decide_device(&mut self, ctx: &DeviceCtx) -> Placement {
        if let Some(p) = pinned_device(ctx) {
            return p;
        }
        self.device_flip = !self.device_flip;
        if self.device_flip {
            Placement::Local
        } else {
            Placement::ToEdge
        }
    }

    fn decide_edge(&mut self, ctx: &EdgeCtx) -> Placement {
        if let Some(p) = pinned_edge(ctx) {
            return p;
        }
        // Profile-blind: every linked non-origin device is a candidate —
        // staleness and suspicion are deliberately ignored.
        let candidates = ctx.candidates.devices();
        // Slot 0 = edge itself, then the candidates in table order.
        let n = candidates.len() + 1;
        let pick = self.edge_idx % n;
        self.edge_idx += 1;
        if pick == 0 {
            Placement::Local
        } else {
            Placement::Offload(candidates[pick - 1].state.node)
        }
    }
}

/// Uniformly random placement (seeded — deterministic per run).
pub struct RandomPolicy {
    rng: SplitMix64,
}

impl RandomPolicy {
    /// A seeded uniformly-random policy.
    pub fn new(rng: SplitMix64) -> Self {
        RandomPolicy { rng }
    }
}

impl SchedulerPolicy for RandomPolicy {
    fn name(&self) -> &'static str {
        "random"
    }

    fn decide_device(&mut self, ctx: &DeviceCtx) -> Placement {
        if let Some(p) = pinned_device(ctx) {
            return p;
        }
        if self.rng.chance(0.5) {
            Placement::Local
        } else {
            Placement::ToEdge
        }
    }

    fn decide_edge(&mut self, ctx: &EdgeCtx) -> Placement {
        if let Some(p) = pinned_edge(ctx) {
            return p;
        }
        let candidates = ctx.candidates.devices();
        let n = candidates.len() + 1;
        let pick = self.rng.choice_index(n);
        if pick == 0 {
            Placement::Local
        } else {
            Placement::Offload(candidates[pick - 1].state.node)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::message::{EdgeSummary, ProfileUpdate};
    use crate::core::{Constraint, ImageMeta, NodeClass, NodeId, TaskId};
    use crate::net::LinkModel;
    use crate::profile::{profile_for, PeerTable, Predictor, ProfileTable};
    use crate::scheduler::{CandidateSnapshot, CloudCandidate, LocalSnapshot, PredictorSet};
    use once_cell::sync::Lazy;
    use std::collections::BTreeSet;

    static RPI_PRED: Lazy<Predictor> =
        Lazy::new(|| Predictor::new(profile_for(NodeClass::RaspberryPi)));
    static PREDICTORS: Lazy<PredictorSet> = Lazy::new(PredictorSet::new);
    static NO_PEERS: Lazy<PeerTable> = Lazy::new(PeerTable::new);
    static NO_SUSPECTS: Lazy<BTreeSet<NodeId>> = Lazy::new(BTreeSet::new);

    fn img(seq: u64, deadline: f64) -> ImageMeta {
        ImageMeta {
            task: TaskId(seq),
            origin: NodeId(1),
            size_kb: 29.0,
            side_px: 64,
            created_ms: 0.0,
            constraint: Constraint::deadline(deadline),
            seq,
        }
    }

    fn device_ctx<'a>(img: &'a ImageMeta, busy: u32, warm: u32, queued: u32) -> DeviceCtx<'a> {
        DeviceCtx {
            now_ms: 0.0,
            img,
            local: LocalSnapshot {
                node: NodeId(1),
                busy_containers: busy,
                warm_containers: warm,
                queued_images: queued,
                cpu_load_pct: 0.0,
                battery_pct: None,
            },
            predictor: &RPI_PRED,
            edge_suspected: false,
        }
    }

    fn table_with_r2(busy: u32, warm: u32) -> ProfileTable {
        let mut t = ProfileTable::new();
        t.register(NodeId(2), NodeClass::RaspberryPi, warm, 0.0);
        t.apply(&ProfileUpdate {
            node: NodeId(2),
            busy_containers: busy,
            warm_containers: warm,
            queued_images: 0,
            cpu_load_pct: 0.0,
            battery_pct: None,
            sent_ms: 0.0,
        });
        t
    }

    /// Build a Wi-Fi-linked candidate snapshot for an edge decision at
    /// t=5 ms (the staleness cap is the classic 200 ms).
    fn snap(
        table: &ProfileTable,
        peers: &PeerTable,
        suspects: &BTreeSet<NodeId>,
        origin: NodeId,
    ) -> CandidateSnapshot {
        CandidateSnapshot::build(table, peers, suspects, origin, 5.0, 200.0, |_| {
            Some(LinkModel::wifi())
        })
    }

    fn edge_ctx<'a>(img: &'a ImageMeta, candidates: &'a CandidateSnapshot) -> EdgeCtx<'a> {
        EdgeCtx {
            now_ms: 5.0,
            img,
            edge: LocalSnapshot {
                node: NodeId(0),
                busy_containers: 0,
                warm_containers: 4,
                queued_images: 0,
                cpu_load_pct: 0.0,
                battery_pct: None,
            },
            predictors: &PREDICTORS,
            candidates,
            forwarded: false,
            hops_left: 1,
            visited: &[],
            app_weight: 1,
            cloud: None,
        }
    }

    /// A federation context: edge pool saturated (`busy` of 4).
    fn fed_ctx<'a>(
        img: &'a ImageMeta,
        candidates: &'a CandidateSnapshot,
        busy: u32,
    ) -> EdgeCtx<'a> {
        EdgeCtx {
            now_ms: 5.0,
            img,
            edge: LocalSnapshot {
                node: NodeId(0),
                busy_containers: busy,
                warm_containers: 4,
                queued_images: 0,
                cpu_load_pct: 0.0,
                battery_pct: None,
            },
            predictors: &PREDICTORS,
            candidates,
            forwarded: false,
            hops_left: 1,
            visited: &[],
            app_weight: 1,
            cloud: None,
        }
    }

    fn peer(edge: u32, busy: u32, warm: u32, sent: f64) -> EdgeSummary {
        EdgeSummary {
            edge: NodeId(edge),
            busy_containers: busy,
            warm_containers: warm,
            queued_images: 0,
            cpu_load_pct: 0.0,
            device_idle_containers: 0,
            sent_ms: sent,
            hops: 0,
            via: NodeId(edge),
        }
    }

    #[test]
    fn aor_always_local() {
        let im = img(0, 1.0); // impossible deadline — AOR doesn't care
        assert_eq!(Aor.decide_device(&device_ctx(&im, 4, 4, 10)), Placement::Local);
    }

    #[test]
    fn aoe_always_edge() {
        let im = img(0, 1e9);
        assert_eq!(Aoe.decide_device(&device_ctx(&im, 0, 4, 0)), Placement::ToEdge);
        let t = table_with_r2(0, 2);
        let s = snap(&t, &NO_PEERS, &NO_SUSPECTS, im.origin);
        assert_eq!(Aoe.decide_edge(&edge_ctx(&im, &s)), Placement::Local);
    }

    #[test]
    fn eods_parity_split() {
        let mut p = Eods;
        let odd = img(1, 1e9);
        let even = img(2, 1e9);
        assert_eq!(p.decide_device(&device_ctx(&odd, 0, 2, 0)), Placement::Local);
        assert_eq!(p.decide_device(&device_ctx(&even, 0, 2, 0)), Placement::ToEdge);
    }

    #[test]
    fn dds_local_when_budget_allows() {
        let mut p = Dds::new();
        // RPi idle single container: 597 ms predicted. Budget 1000 → local.
        let im = img(0, 1000.0);
        assert_eq!(p.decide_device(&device_ctx(&im, 0, 1, 0)), Placement::Local);
        // Budget 500 < 597 → forward to edge (the paper's exact example:
        // "if a job's running time is 597 ms ... and the time constraint is
        // less than this number, the task is sent to the edge server").
        let im = img(0, 500.0);
        assert_eq!(p.decide_device(&device_ctx(&im, 0, 1, 0)), Placement::ToEdge);
    }

    #[test]
    fn dds_accounts_for_queue() {
        let mut p = Dds::new();
        // Saturated pool + queue → predicted way beyond 1000 ms budget.
        let im = img(0, 1000.0);
        assert_eq!(p.decide_device(&device_ctx(&im, 2, 2, 6)), Placement::ToEdge);
    }

    #[test]
    fn dds_edge_offloads_to_idle_device() {
        let mut p = Dds::new();
        let im = img(0, 5000.0);
        let t = table_with_r2(0, 2);
        let s = snap(&t, &NO_PEERS, &NO_SUSPECTS, im.origin);
        let got = p.decide_edge(&edge_ctx(&im, &s));
        assert_eq!(got, Placement::Offload(NodeId(2)));
    }

    #[test]
    fn dds_edge_keeps_local_when_device_busy() {
        let mut p = Dds::new();
        let im = img(0, 5000.0);
        let t = table_with_r2(2, 2); // no idle containers on R2
        let s = snap(&t, &NO_PEERS, &NO_SUSPECTS, im.origin);
        let got = p.decide_edge(&edge_ctx(&im, &s));
        assert_eq!(got, Placement::Local);
    }

    #[test]
    fn dds_no_avail_ignores_busy() {
        let mut p = DdsNoAvail::new();
        let im = img(0, 50_000.0);
        let t = table_with_r2(2, 2);
        let s = snap(&t, &NO_PEERS, &NO_SUSPECTS, im.origin);
        let got = p.decide_edge(&edge_ctx(&im, &s));
        assert_eq!(got, Placement::Offload(NodeId(2)));
    }

    #[test]
    fn dds_edge_local_when_budget_too_tight_for_device() {
        let mut p = Dds::new();
        // 300 ms budget: RPi needs 597+ — edge must keep it.
        let im = img(0, 300.0);
        let t = table_with_r2(0, 2);
        let s = snap(&t, &NO_PEERS, &NO_SUSPECTS, im.origin);
        let got = p.decide_edge(&edge_ctx(&im, &s));
        assert_eq!(got, Placement::Local);
    }

    #[test]
    fn dds_edge_skips_stale_profiles() {
        let mut p = Dds::new();
        let im = img(0, 5000.0);
        let mut t = table_with_r2(0, 2);
        // Make the profile ancient relative to the snapshot's now = 5.0.
        t.apply(&ProfileUpdate {
            node: NodeId(2),
            busy_containers: 0,
            warm_containers: 2,
            queued_images: 0,
            cpu_load_pct: 0.0,
            battery_pct: None,
            sent_ms: -10_000.0,
        });
        let s = snap(&t, &NO_PEERS, &NO_SUSPECTS, im.origin);
        let got = p.decide_edge(&edge_ctx(&im, &s));
        assert_eq!(got, Placement::Local);
    }

    #[test]
    fn dds_never_offloads_to_origin() {
        let mut p = Dds::new();
        let im = img(0, 5000.0);
        let mut t = ProfileTable::new();
        t.register(NodeId(1), NodeClass::RaspberryPi, 2, 0.0); // origin itself
        let s = snap(&t, &NO_PEERS, &NO_SUSPECTS, im.origin);
        let got = p.decide_edge(&edge_ctx(&im, &s));
        assert_eq!(got, Placement::Local);
    }

    #[test]
    fn pinned_constraint_overrides_everything() {
        let mut dds = Dds::new();
        let mut im = img(0, 1.0);
        im.constraint = Constraint::pinned(1.0, NodeId(1));
        assert_eq!(dds.decide_device(&device_ctx(&im, 4, 4, 50)), Placement::Local);
        im.constraint = Constraint::pinned(1.0, NodeId(2));
        let t = table_with_r2(2, 2);
        let s = snap(&t, &NO_PEERS, &NO_SUSPECTS, im.origin);
        assert_eq!(dds.decide_edge(&edge_ctx(&im, &s)), Placement::Offload(NodeId(2)));
    }

    // ---- federation-level decision ----------------------------------

    #[test]
    fn dds_federates_when_cell_exhausted() {
        let mut p = Dds::new();
        let im = img(0, 5_000.0);
        let t = ProfileTable::new(); // no devices in this cell
        let mut peers = PeerTable::new();
        peers.apply(&peer(3, 0, 4, 0.0));
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        let got = p.decide_edge(&fed_ctx(&im, &s, 4));
        assert_eq!(got, Placement::ToPeerEdge(NodeId(3)));
    }

    #[test]
    fn dds_prefers_own_pool_over_peer() {
        let mut p = Dds::new();
        let im = img(0, 5_000.0);
        let t = ProfileTable::new();
        let mut peers = PeerTable::new();
        peers.apply(&peer(3, 0, 4, 0.0));
        // One idle edge container: keep the task in the cell.
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        let got = p.decide_edge(&fed_ctx(&im, &s, 3));
        assert_eq!(got, Placement::Local);
    }

    #[test]
    fn dds_prefers_cell_device_over_peer() {
        let mut p = Dds::new();
        let im = img(0, 5_000.0);
        let t = table_with_r2(0, 2); // idle device in the cell
        let mut peers = PeerTable::new();
        peers.apply(&peer(3, 0, 4, 0.0));
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        let got = p.decide_edge(&fed_ctx(&im, &s, 4));
        assert_eq!(got, Placement::Offload(NodeId(2)));
    }

    #[test]
    fn spent_hop_budget_blocks_federation() {
        // A frame whose hop budget is exhausted (legacy Forward frames
        // decode to exactly this) stays put even with an idle fresh peer.
        let mut p = Dds::new();
        let im = img(0, 5_000.0);
        let t = ProfileTable::new();
        let mut peers = PeerTable::new();
        peers.apply(&peer(3, 0, 4, 0.0));
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        let mut ctx = fed_ctx(&im, &s, 4);
        ctx.forwarded = true;
        ctx.hops_left = 0;
        assert_eq!(p.decide_edge(&ctx), Placement::Local);
    }

    #[test]
    fn forwarded_frame_with_budget_may_hop_again() {
        // Hierarchical routing: an intermediate cell that is itself
        // exhausted re-forwards while TTL remains — but never back to an
        // edge on the visited path.
        let mut p = Dds::new();
        let im = img(0, 5_000.0);
        let t = ProfileTable::new();
        let mut peers = PeerTable::new();
        peers.apply(&peer(6, 0, 4, 0.0));
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        let visited = [NodeId(0)];
        let mut ctx = fed_ctx(&im, &s, 4);
        ctx.forwarded = true;
        ctx.hops_left = 1;
        ctx.visited = &visited;
        assert_eq!(p.decide_edge(&ctx), Placement::ToPeerEdge(NodeId(6)));
        // The frame's originating edge is never a target again.
        let visited_all = [NodeId(0), NodeId(6)];
        ctx.visited = &visited_all;
        assert_eq!(p.decide_edge(&ctx), Placement::Local);
    }

    #[test]
    fn multi_hop_subject_needs_enough_budget() {
        // A subject learned two relays away takes three sends to reach:
        // with hops_left = 1 it is not a candidate, with 3 it is.
        let mut p = Dds::new();
        let im = img(0, 50_000.0);
        let t = ProfileTable::new();
        let mut peers = PeerTable::new();
        let mut far = peer(9, 0, 4, 0.0);
        far.hops = 2;
        far.via = NodeId(3);
        peers.apply(&far);
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        let mut ctx = fed_ctx(&im, &s, 4);
        ctx.hops_left = 1;
        assert_eq!(p.decide_edge(&ctx), Placement::Local);
        ctx.hops_left = 3;
        assert_eq!(p.decide_edge(&ctx), Placement::ToPeerEdge(NodeId(9)));
    }

    #[test]
    fn nearer_cell_wins_at_equal_load_and_queue_depth_dominates() {
        let mut p = Dds::new();
        let im = img(0, 50_000.0);
        let t = ProfileTable::new();
        let mut peers = PeerTable::new();
        // Direct neighbor and a 1-hop-relayed subject, identical state:
        // the nearer cell wins.
        peers.apply(&peer(3, 0, 4, 0.0));
        let mut far = peer(6, 0, 4, 0.0);
        far.hops = 1;
        far.via = NodeId(3);
        peers.apply(&far);
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        let mut ctx = fed_ctx(&im, &s, 4);
        ctx.hops_left = 2;
        assert_eq!(p.decide_edge(&ctx), Placement::ToPeerEdge(NodeId(3)));
        // … but a queue-free far cell beats a backlogged neighbor: load
        // awareness dominates hop distance.
        let mut backlogged = peer(3, 0, 4, 1.0);
        backlogged.queued_images = 5;
        peers.apply(&backlogged);
        let mut far = peer(6, 0, 4, 1.0);
        far.hops = 1;
        far.via = NodeId(3);
        peers.apply(&far);
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        let mut ctx = fed_ctx(&im, &s, 4);
        ctx.hops_left = 2;
        assert_eq!(p.decide_edge(&ctx), Placement::ToPeerEdge(NodeId(6)));
    }

    #[test]
    fn app_weight_discounts_advertised_queue_depth() {
        // Two peers: n3 backlogged (4 queued) but nearer in NodeId order,
        // n6 lightly queued (1). A weight-1 app sees depths 4 vs 1 and
        // picks n6; a weight-8 app sees 0.5 vs 0.125 and still picks n6 —
        // but against an *empty* n3 the weighted depths tie at 0 and the
        // hop/time/NodeId tie-break applies. The weight changes the
        // comparison scale, not the winner ordering of equal depths.
        let mut p = Dds::new();
        let im = img(0, 50_000.0);
        let t = ProfileTable::new();
        let mut peers = PeerTable::new();
        let mut near = peer(3, 0, 4, 0.0);
        near.queued_images = 4;
        peers.apply(&near);
        let mut far = peer(6, 0, 4, 0.0);
        far.queued_images = 1;
        peers.apply(&far);
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        let mut ctx = fed_ctx(&im, &s, 4);
        ctx.app_weight = 1;
        assert_eq!(p.decide_edge(&ctx), Placement::ToPeerEdge(NodeId(6)));
        ctx.app_weight = 8;
        assert_eq!(
            p.decide_edge(&ctx),
            Placement::ToPeerEdge(NodeId(6)),
            "weights rescale depths uniformly"
        );
        // Equal queued depths: weighted depths tie regardless of weight →
        // deterministic NodeId tie-break.
        let mut a = peer(3, 0, 4, 1.0);
        a.queued_images = 2;
        peers.apply(&a);
        let mut b = peer(6, 0, 4, 1.0);
        b.queued_images = 2;
        peers.apply(&b);
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        let mut ctx = fed_ctx(&im, &s, 4);
        ctx.app_weight = 3;
        assert_eq!(p.decide_edge(&ctx), Placement::ToPeerEdge(NodeId(3)));
    }

    #[test]
    fn stale_gossip_blocks_federation() {
        let mut p = Dds::new();
        let im = img(0, 5_000.0);
        let t = ProfileTable::new();
        let mut peers = PeerTable::new();
        peers.apply(&peer(3, 0, 4, -10_000.0)); // ancient summary
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        assert_eq!(p.decide_edge(&fed_ctx(&im, &s, 4)), Placement::Local);
    }

    #[test]
    fn saturated_peer_is_skipped() {
        let mut p = Dds::new();
        let im = img(0, 5_000.0);
        let t = ProfileTable::new();
        let mut peers = PeerTable::new();
        peers.apply(&peer(3, 4, 4, 0.0)); // peer pool full, no device slack
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        assert_eq!(p.decide_edge(&fed_ctx(&im, &s, 4)), Placement::Local);
        // Device slack behind the peer edge counts as capacity.
        let mut sum = peer(3, 4, 4, 0.0);
        sum.device_idle_containers = 2;
        peers.apply(&sum);
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        assert_eq!(
            p.decide_edge(&fed_ctx(&im, &s, 4)),
            Placement::ToPeerEdge(NodeId(3))
        );
    }

    #[test]
    fn least_loaded_peer_wins_ties_by_id() {
        let mut p = Dds::new();
        let im = img(0, 50_000.0);
        let t = ProfileTable::new();
        let mut peers = PeerTable::new();
        peers.apply(&peer(6, 0, 4, 0.0));
        peers.apply(&peer(3, 0, 4, 0.0)); // identical state, lower id
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        assert_eq!(
            p.decide_edge(&fed_ctx(&im, &s, 4)),
            Placement::ToPeerEdge(NodeId(3))
        );
        // A strictly less-loaded peer beats the id tie-break.
        peers.apply(&peer(6, 0, 4, 1.0));
        peers.apply(&peer(3, 3, 4, 1.0));
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        assert_eq!(
            p.decide_edge(&fed_ctx(&im, &s, 4)),
            Placement::ToPeerEdge(NodeId(6))
        );
    }

    #[test]
    fn dds_energy_federates_like_dds() {
        let mut p = DdsEnergy::new(20.0);
        let im = img(0, 5_000.0);
        let t = ProfileTable::new();
        let mut peers = PeerTable::new();
        peers.apply(&peer(3, 0, 4, 0.0));
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        assert_eq!(
            p.decide_edge(&fed_ctx(&im, &s, 4)),
            Placement::ToPeerEdge(NodeId(3))
        );
    }

    #[test]
    fn baselines_never_federate() {
        let im = img(2, 5_000.0); // even seq → EODS would go to edge
        let t = ProfileTable::new();
        let mut peers = PeerTable::new();
        peers.apply(&peer(3, 0, 4, 0.0));
        let s = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        let mut baselines: Vec<Box<dyn SchedulerPolicy>> = vec![
            Box::new(Aor),
            Box::new(Aoe),
            Box::new(Eods),
            Box::new(RoundRobin::default()),
            Box::new(RandomPolicy::new(SplitMix64::new(7))),
        ];
        for b in baselines.iter_mut() {
            for _ in 0..8 {
                let got = b.decide_edge(&fed_ctx(&im, &s, 4));
                assert!(
                    !matches!(got, Placement::ToPeerEdge(_)),
                    "{} must not federate",
                    b.name()
                );
            }
        }
    }

    // ---- privacy hard filters (DESIGN.md §Constraints & QoS) ---------

    #[test]
    fn device_local_frames_never_leave_the_device() {
        use crate::core::AppId;
        // 500 ms budget < 597 ms local prediction: DDS would normally
        // forward — the device-local scope forbids it.
        let mut im = img(0, 500.0);
        im.constraint = crate::core::Constraint::for_app(
            AppId(1),
            500.0,
            crate::core::PrivacyClass::DeviceLocal,
            0,
        );
        let mut dds = Dds::new();
        assert_eq!(dds.decide_device(&device_ctx(&im, 0, 1, 0)), Placement::Local);
        // The energy variant keeps it local even below the battery reserve.
        let mut e = DdsEnergy::new(20.0);
        let mut ctx = device_ctx(&im, 0, 1, 0);
        ctx.local.battery_pct = Some(5.0);
        assert_eq!(e.decide_device(&ctx), Placement::Local);
    }

    #[test]
    fn cell_local_frames_never_cross_the_backhaul() {
        use crate::core::AppId;
        // Cell exhausted, fresh idle peer available: an open frame
        // federates, a cell-local one must stay (edge queue).
        let t = ProfileTable::new();
        let mut peers = PeerTable::new();
        peers.apply(&peer(3, 0, 4, 0.0));
        let mut p = Dds::new();
        let open = img(0, 5_000.0);
        let s = snap(&t, &peers, &NO_SUSPECTS, open.origin);
        assert_eq!(
            p.decide_edge(&fed_ctx(&open, &s, 4)),
            Placement::ToPeerEdge(NodeId(3))
        );
        let mut bound = img(1, 5_000.0);
        bound.constraint = crate::core::Constraint::for_app(
            AppId(2),
            5_000.0,
            crate::core::PrivacyClass::CellLocal,
            0,
        );
        assert_eq!(p.decide_edge(&fed_ctx(&bound, &s, 4)), Placement::Local);
        // Cell-local frames may still offload *within* the cell.
        let t2 = table_with_r2(0, 2);
        let s2 = snap(&t2, &NO_PEERS, &NO_SUSPECTS, bound.origin);
        assert_eq!(
            p.decide_edge(&edge_ctx(&bound, &s2)),
            Placement::Offload(NodeId(2))
        );
        // The energy variant applies the same backhaul filter.
        let mut e = DdsEnergy::new(20.0);
        assert_eq!(e.decide_edge(&fed_ctx(&bound, &s, 4)), Placement::Local);
    }

    // ---- elastic cloud tier (DESIGN.md §4e) --------------------------

    /// The default §4e uplink: 40 ms WAN RTT share, 10 Gbps, lossless.
    fn cloud9() -> CloudCandidate {
        CloudCandidate { node: NodeId(9), uplink: LinkModel::new(40.0, 10_000.0, 0.0) }
    }

    #[test]
    fn cloud_is_last_resort_after_federation() {
        let mut p = Dds::new();
        let im = img(0, 5_000.0);
        // No peers, pool exhausted, cloud present → ToCloud.
        let t = ProfileTable::new();
        let s = snap(&t, &NO_PEERS, &NO_SUSPECTS, im.origin);
        let mut ctx = fed_ctx(&im, &s, 4);
        ctx.cloud = Some(cloud9());
        assert_eq!(p.decide_edge(&ctx), Placement::ToCloud(NodeId(9)));
        // A feasible idle peer outranks the cloud — federation first.
        let mut peers = PeerTable::new();
        peers.apply(&peer(3, 0, 4, 0.0));
        let s2 = snap(&t, &peers, &NO_SUSPECTS, im.origin);
        let mut ctx2 = fed_ctx(&im, &s2, 4);
        ctx2.cloud = Some(cloud9());
        assert_eq!(p.decide_edge(&ctx2), Placement::ToPeerEdge(NodeId(3)));
        // Pool not exhausted → local, never cloud.
        let mut ctx3 = fed_ctx(&im, &s, 2);
        ctx3.cloud = Some(cloud9());
        assert_eq!(p.decide_edge(&ctx3), Placement::Local);
        // The energy variant sheds to the cloud under the same rule.
        let mut e = DdsEnergy::new(20.0);
        let mut ctx4 = fed_ctx(&im, &s, 4);
        ctx4.cloud = Some(cloud9());
        assert_eq!(e.decide_edge(&ctx4), Placement::ToCloud(NodeId(9)));
    }

    #[test]
    fn cloud_respects_privacy_scopes() {
        use crate::core::AppId;
        let t = ProfileTable::new();
        let mut p = Dds::new();
        for privacy in [crate::core::PrivacyClass::CellLocal, crate::core::PrivacyClass::DeviceLocal]
        {
            let mut im = img(1, 5_000.0);
            im.constraint = Constraint::for_app(AppId(2), 5_000.0, privacy, 0);
            let s = snap(&t, &NO_PEERS, &NO_SUSPECTS, im.origin);
            let mut ctx = fed_ctx(&im, &s, 4);
            ctx.cloud = Some(cloud9());
            assert_eq!(
                p.decide_edge(&ctx),
                Placement::Local,
                "{privacy:?} frames must never traverse the uplink"
            );
        }
    }

    #[test]
    fn cloud_declines_when_budget_too_tight() {
        // 100 ms budget < 40 ms uplink + ~178 ms cloud execution: the
        // frame queues locally rather than missing in flight.
        let im = img(0, 100.0);
        let t = ProfileTable::new();
        let s = snap(&t, &NO_PEERS, &NO_SUSPECTS, im.origin);
        let mut ctx = fed_ctx(&im, &s, 4);
        ctx.cloud = Some(cloud9());
        let mut p = Dds::new();
        assert_eq!(p.decide_edge(&ctx), Placement::Local);
    }

    #[test]
    fn baselines_are_cloud_blind() {
        let im = img(2, 5_000.0);
        let t = ProfileTable::new();
        let s = snap(&t, &NO_PEERS, &NO_SUSPECTS, im.origin);
        let mut baselines: Vec<Box<dyn SchedulerPolicy>> = vec![
            Box::new(Aor),
            Box::new(Aoe),
            Box::new(Eods),
            Box::new(RoundRobin::default()),
            Box::new(RandomPolicy::new(SplitMix64::new(7))),
        ];
        for b in baselines.iter_mut() {
            for _ in 0..8 {
                let mut ctx = fed_ctx(&im, &s, 4);
                ctx.cloud = Some(cloud9());
                let got = b.decide_edge(&ctx);
                assert!(
                    !matches!(got, Placement::ToCloud(_)),
                    "{} must not use the cloud tier",
                    b.name()
                );
            }
        }
    }

    #[test]
    fn edge_prediction_ties_break_by_node_id() {
        // Two identical idle devices → identical predictions; the lower
        // NodeId must win regardless of registration order (EDF-style
        // deterministic tie-break).
        use crate::core::message::ProfileUpdate;
        let mut t = ProfileTable::new();
        for node in [5u32, 2] {
            t.register(NodeId(node), NodeClass::RaspberryPi, 2, 0.0);
            t.apply(&ProfileUpdate {
                node: NodeId(node),
                busy_containers: 0,
                warm_containers: 2,
                queued_images: 0,
                cpu_load_pct: 0.0,
                battery_pct: None,
                sent_ms: 0.0,
            });
        }
        let im = img(0, 5_000.0);
        let s = snap(&t, &NO_PEERS, &NO_SUSPECTS, im.origin);
        let mut p = Dds::new();
        assert_eq!(p.decide_edge(&edge_ctx(&im, &s)), Placement::Offload(NodeId(2)));
    }

    // ---- churn / failure suspicion (DESIGN.md §Churn) ----------------

    #[test]
    fn dds_device_keeps_local_when_edge_suspected() {
        let mut p = Dds::new();
        // 500 ms budget < 597 ms local prediction: normally ToEdge …
        let im = img(0, 500.0);
        assert_eq!(p.decide_device(&device_ctx(&im, 0, 1, 0)), Placement::ToEdge);
        // … but with the edge suspected down, the frame stays local.
        let mut ctx = device_ctx(&im, 0, 1, 0);
        ctx.edge_suspected = true;
        assert_eq!(p.decide_device(&ctx), Placement::Local);
        // The energy variant behaves the same.
        let mut e = DdsEnergy::new(20.0);
        let mut ctx = device_ctx(&im, 0, 1, 0);
        ctx.edge_suspected = true;
        ctx.local.battery_pct = Some(5.0); // below reserve, still local
        assert_eq!(e.decide_device(&ctx), Placement::Local);
    }

    #[test]
    fn baselines_ignore_edge_suspicion() {
        // AOE/EODS are churn-blind by design: they keep throwing frames at
        // the (dead) edge — the contrast the churn experiment measures.
        let even = img(2, 5_000.0);
        let mut ctx = device_ctx(&even, 0, 2, 0);
        ctx.edge_suspected = true;
        assert_eq!(Aoe.decide_device(&ctx), Placement::ToEdge);
        assert_eq!(Eods.decide_device(&ctx), Placement::ToEdge);
    }

    #[test]
    fn dds_edge_skips_suspected_device() {
        let mut p = Dds::new();
        let im = img(0, 5_000.0);
        let t = table_with_r2(0, 2); // fresh + idle — normally offloaded to
        let mut suspects = BTreeSet::new();
        suspects.insert(NodeId(2));
        let s = snap(&t, &NO_PEERS, &suspects, im.origin);
        assert_eq!(p.decide_edge(&edge_ctx(&im, &s)), Placement::Local);
        // DdsEnergy applies the same filter.
        let mut e = DdsEnergy::new(20.0);
        assert_eq!(e.decide_edge(&edge_ctx(&im, &s)), Placement::Local);
    }

    #[test]
    fn suspected_peer_edge_is_not_a_forward_target() {
        let mut p = Dds::new();
        let im = img(0, 5_000.0);
        let t = ProfileTable::new();
        let mut peers = PeerTable::new();
        peers.apply(&peer(3, 0, 4, 0.0)); // fresh + idle peer
        let mut suspects = BTreeSet::new();
        suspects.insert(NodeId(3));
        let s = snap(&t, &peers, &suspects, im.origin);
        assert_eq!(p.decide_edge(&fed_ctx(&im, &s, 4)), Placement::Local);
    }

    #[test]
    fn round_robin_alternates() {
        let mut p = RoundRobin::default();
        let im = img(0, 1e9);
        let a = p.decide_device(&device_ctx(&im, 0, 1, 0));
        let b = p.decide_device(&device_ctx(&im, 0, 1, 0));
        assert_ne!(a, b);
    }

    #[test]
    fn round_robin_cycles_stale_candidates_too() {
        // Profile-blind baselines ignore the snapshot's freshness flags:
        // a stale device still takes its round-robin slot.
        let mut t = table_with_r2(0, 2);
        t.apply(&ProfileUpdate {
            node: NodeId(2),
            busy_containers: 0,
            warm_containers: 2,
            queued_images: 0,
            cpu_load_pct: 0.0,
            battery_pct: None,
            sent_ms: -10_000.0, // ancient
        });
        let im = img(0, 1e9);
        let s = snap(&t, &NO_PEERS, &NO_SUSPECTS, im.origin);
        let mut p = RoundRobin::default();
        let picks: Vec<Placement> =
            (0..4).map(|_| p.decide_edge(&edge_ctx(&im, &s))).collect();
        assert_eq!(
            picks,
            vec![
                Placement::Local,
                Placement::Offload(NodeId(2)),
                Placement::Local,
                Placement::Offload(NodeId(2)),
            ]
        );
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let im = img(0, 1e9);
        let run = |seed| {
            let mut p = RandomPolicy::new(SplitMix64::new(seed));
            (0..16)
                .map(|_| matches!(p.decide_device(&device_ctx(&im, 0, 1, 0)), Placement::Local))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }
}
