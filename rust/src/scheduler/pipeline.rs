//! The staged scheduling pipeline (DESIGN.md §3).
//!
//! Every frame's decision path is the same explicit stage sequence,
//! driven by both node classes:
//!
//! ```text
//! Admit → Filter → Place → Dispatch → Overload
//! ```
//!
//! - **Admit** (edge only): per-app token-bucket rate limiting plus a
//!   per-app ceiling on the edge pool's overflow queue (`[admission]`
//!   config). Disabled (a structural no-op) unless configured.
//! - **Filter**: the privacy/suspect clamps that used to live ad-hoc in
//!   `DeviceNode`/`EdgeNode` — [`device_intake`], [`edge_intake`],
//!   [`clamp_placement`] — plus the [`CandidateSnapshot`]: one pass over
//!   the MP and peer tables resolving staleness, suspicion and links, so
//!   the Place stage never re-scans tables or re-hashes link lookups.
//! - **Place**: the policy's three decision levels
//!   ([`SchedulerPolicy::decide_device`] / `decide_edge`), consuming the
//!   snapshot.
//! - **Dispatch**: container-pool ordering — strict (priority, EDF,
//!   task) by default, weighted-fair DRR when `[[app]] weight` keys are
//!   present (see [`crate::container::QueueDiscipline`]).
//! - **Overload**: deadline-aware shedding of best-effort frames whose
//!   predicted completion already exceeds their deadline
//!   ([`should_shed`]) — drop at enqueue, not after wasting a container.
//!
//! Legacy configs (no `[admission]`, no `weight` keys) flow through the
//! same stages with Admit and Overload structurally inert and Dispatch in
//! strict mode: the decision sequence — and therefore the seeded replay —
//! is byte-identical to the pre-pipeline code.
//!
//! The Place stage's federation level consumes the snapshot's *peer*
//! candidates, which may sit several backhaul hops away (hierarchical
//! routing, DESIGN.md §4a):
//!
//! ```text
//!  PeerTable entry:   subject ◄─ hops ─┐ via (next hop, direct link)
//!  ToPeerEdge(subject) ⇒ Forward{ttl, visited} sent to `via`
//!                        `via` re-decides with its own fresher tables
//! ```
//!
//! [`SchedulerPolicy::decide_device`]: super::SchedulerPolicy::decide_device

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::fmt;

use crate::container::ContainerPool;
use crate::core::{AppId, ImageMeta, NodeId, Placement, PrivacyClass};
use crate::metrics::trace::{SharedTrace, TraceEvent};
use crate::net::LinkModel;
use crate::util::Hist;
use crate::profile::{DeviceState, PeerEdgeState, PeerTable, ProfileTable};

// ---------------------------------------------------------------------
// Filter stage, device side.
// ---------------------------------------------------------------------

/// Verdict of the device-level Filter stage, applied *before* the policy
/// (privacy is a constraint, not a preference — DESIGN.md §4c).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceIntake {
    /// A `device_local` frame never leaves its origin, whatever any
    /// policy would decide. `infeasible` marks the collision with a
    /// depleted battery: the device can neither compute nor disclose, so
    /// the frame is lost outright.
    ClampLocal { infeasible: bool },
    /// A depleted device cannot compute at all — every disclosable frame
    /// forwards to the edge.
    ForceForward,
    /// No clamp applies: the Place stage (policy) decides.
    Place,
}

/// Device-level Filter: privacy clamp first, battery feasibility second.
pub fn device_intake(privacy: PrivacyClass, depleted: bool) -> DeviceIntake {
    if privacy == PrivacyClass::DeviceLocal {
        DeviceIntake::ClampLocal { infeasible: depleted }
    } else if depleted {
        DeviceIntake::ForceForward
    } else {
        DeviceIntake::Place
    }
}

// ---------------------------------------------------------------------
// Filter stage, edge side.
// ---------------------------------------------------------------------

/// Verdict of the edge-level pre-place Filter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeIntake {
    /// A `device_local` frame at the edge is a protocol violation (no
    /// compliant device forwards one): return it to its origin,
    /// untracked — tracking would leak relay state, since the origin
    /// resolves its own frames without reporting a Result.
    ReturnToOrigin,
    /// Schedulable: continue to Admit/Place.
    Schedule,
}

/// Edge-level pre-place Filter.
pub fn edge_intake(privacy: PrivacyClass) -> EdgeIntake {
    if privacy == PrivacyClass::DeviceLocal {
        EdgeIntake::ReturnToOrigin
    } else {
        EdgeIntake::Schedule
    }
}

/// Edge-level post-place clamp, enforced for *every* policy — including
/// the churn requeue path, which re-enters the pipeline: a `cell_local`
/// frame never crosses the backhaul, whatever the Place stage decided.
/// The cloud uplink (DESIGN.md §4e) is open-only: both constrained
/// classes clamp `ToCloud` back to `Local`, so no policy bug — present or
/// future — can leak a constrained frame up the WAN.
pub fn clamp_placement(privacy: PrivacyClass, placement: Placement) -> Placement {
    match (privacy, placement) {
        (PrivacyClass::CellLocal, Placement::ToPeerEdge(_)) => Placement::Local,
        (PrivacyClass::CellLocal, Placement::ToCloud(_)) => Placement::Local,
        (PrivacyClass::DeviceLocal, Placement::ToCloud(_)) => Placement::Local,
        (_, p) => p,
    }
}

// ---------------------------------------------------------------------
// Candidate snapshot (Filter stage output consumed by Place).
// ---------------------------------------------------------------------

/// One in-cell offload candidate: its MP state with staleness, suspicion
/// and the edge→device link resolved once per decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceCandidate {
    /// The candidate’s MP state.
    pub state: DeviceState,
    /// Link from the deciding edge to the candidate.
    pub link: LinkModel,
    /// Last UP push within the staleness cap at decision time.
    pub fresh: bool,
    /// Currently suspected down by the failure detector.
    pub suspect: bool,
}

/// One peer-edge forwarding candidate (federation level). Multi-hop
/// subjects (learned through transitive gossip) are candidates too: their
/// `link` is the backhaul link to the *next hop* (`state.via`), the only
/// edge this cell can actually reach.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PeerCandidate {
    /// The gossiped summary, with hop distance and next hop resolved.
    pub state: PeerEdgeState,
    /// Link to the next hop toward the subject (`state.via`).
    pub link: LinkModel,
    /// Last gossip (subject-side vintage) within the staleness cap.
    pub fresh: bool,
    /// Currently suspected down by the failure detector.
    pub suspect: bool,
}

/// The per-decision candidate snapshot: MP and peer tables resolved in
/// one pass — deterministic registration order, the frame's origin
/// excluded, link-less nodes dropped (they could never be targets). The
/// Place stage's three levels all read this instead of re-scanning the
/// tables, re-probing the suspect set, and re-hashing link lookups per
/// candidate.
#[derive(Debug, Clone, Default)]
pub struct CandidateSnapshot {
    devices: Vec<DeviceCandidate>,
    peers: Vec<PeerCandidate>,
    /// Node → index into `devices`, maintained by `rebuild` so table
    /// deltas can patch one entry without rescanning (incremental
    /// maintenance — the city-scale hot path).
    device_pos: HashMap<NodeId, usize>,
    /// Subject edge → index into `peers` (see `device_pos`).
    peer_pos: HashMap<NodeId, usize>,
}

impl CandidateSnapshot {
    /// An empty snapshot (filled by [`CandidateSnapshot::rebuild`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// In-cell candidates, MP registration order, origin excluded.
    /// Includes stale/suspected entries (flagged) — the profile-blind
    /// baselines deliberately ignore freshness.
    pub fn devices(&self) -> &[DeviceCandidate] {
        &self.devices
    }

    /// Peer-edge candidates, registration order.
    pub fn peers(&self) -> &[PeerCandidate] {
        &self.peers
    }

    /// Rebuild in place (allocation-free after warmup).
    #[allow(clippy::too_many_arguments)]
    pub fn rebuild(
        &mut self,
        table: &ProfileTable,
        peers: &PeerTable,
        suspects: &BTreeSet<NodeId>,
        origin: NodeId,
        now_ms: f64,
        max_staleness_ms: f64,
        link_to: impl Fn(NodeId) -> Option<LinkModel>,
    ) {
        self.devices.clear();
        self.peers.clear();
        self.device_pos.clear();
        self.peer_pos.clear();
        for s in table.iter() {
            if s.node == origin {
                continue;
            }
            let Some(link) = link_to(s.node) else { continue };
            self.device_pos.insert(s.node, self.devices.len());
            self.devices.push(DeviceCandidate {
                state: *s,
                link,
                fresh: now_ms - s.updated_ms <= max_staleness_ms,
                suspect: suspects.contains(&s.node),
            });
        }
        for p in peers.iter() {
            // The link that matters is the one to the next hop: a
            // multi-hop subject has no direct backhaul link on a line
            // topology, but its `via` neighbor does.
            let Some(link) = link_to(p.via) else { continue };
            self.peer_pos.insert(p.edge, self.peers.len());
            self.peers.push(PeerCandidate {
                state: *p,
                link,
                fresh: now_ms - p.updated_ms <= max_staleness_ms,
                // A suspected next hop blocks the route as surely as a
                // suspected subject.
                suspect: suspects.contains(&p.edge) || suspects.contains(&p.via),
            });
        }
    }

    /// Re-resolve every candidate's staleness flag at a new instant — the
    /// only per-entry field that depends on `now` alone.
    fn refresh_staleness(&mut self, now_ms: f64, max_staleness_ms: f64) {
        for c in &mut self.devices {
            c.fresh = now_ms - c.state.updated_ms <= max_staleness_ms;
        }
        for c in &mut self.peers {
            c.fresh = now_ms - c.state.updated_ms <= max_staleness_ms;
        }
    }

    /// Patch one device candidate in place from its current table entry.
    /// Returns `false` on a *structural* change — an entry would have to
    /// be inserted or removed — which the caller resolves with a full
    /// rebuild (candidate order is registration order; splicing in place
    /// cannot reproduce it in general).
    fn patch_device(
        &mut self,
        node: NodeId,
        table: &ProfileTable,
        suspects: &BTreeSet<NodeId>,
        origin: NodeId,
        now_ms: f64,
        max_staleness_ms: f64,
        link_to: impl Fn(NodeId) -> Option<LinkModel>,
    ) -> bool {
        if node == origin {
            return true; // the origin is never a candidate
        }
        match (table.get(node), self.device_pos.get(&node)) {
            (Some(s), Some(&i)) => {
                let Some(link) = link_to(node) else { return false };
                self.devices[i] = DeviceCandidate {
                    state: *s,
                    link,
                    fresh: now_ms - s.updated_ms <= max_staleness_ms,
                    suspect: suspects.contains(&node),
                };
                true
            }
            // In the table but not the snapshot: fine as long as it could
            // never be a candidate (link-less); an insertion otherwise.
            (Some(_), None) => link_to(node).is_none(),
            // Deregistered since the snapshot was built: a removal.
            (None, Some(_)) => false,
            // A mutation on a node the snapshot never held (e.g. a UP push
            // from an unregistered sender): nothing to patch.
            (None, None) => true,
        }
    }

    /// Patch one peer candidate in place (see [`Self::patch_device`]).
    fn patch_peer(
        &mut self,
        edge: NodeId,
        peers: &PeerTable,
        suspects: &BTreeSet<NodeId>,
        now_ms: f64,
        max_staleness_ms: f64,
        link_to: impl Fn(NodeId) -> Option<LinkModel>,
    ) -> bool {
        match (peers.get(edge), self.peer_pos.get(&edge)) {
            (Some(p), Some(&i)) => {
                // The entry's `via` may have moved to a link-less next hop
                // (relayed copy applied): the candidate must disappear.
                let Some(link) = link_to(p.via) else { return false };
                self.peers[i] = PeerCandidate {
                    state: *p,
                    link,
                    fresh: now_ms - p.updated_ms <= max_staleness_ms,
                    suspect: suspects.contains(&p.edge) || suspects.contains(&p.via),
                };
                true
            }
            (Some(p), None) => link_to(p.via).is_none(),
            (None, Some(_)) => false,
            (None, None) => true,
        }
    }

    /// Build a fresh snapshot (tests / benches / custom drivers).
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        table: &ProfileTable,
        peers: &PeerTable,
        suspects: &BTreeSet<NodeId>,
        origin: NodeId,
        now_ms: f64,
        max_staleness_ms: f64,
        link_to: impl Fn(NodeId) -> Option<LinkModel>,
    ) -> Self {
        let mut s = Self::new();
        s.rebuild(table, peers, suspects, origin, now_ms, max_staleness_ms, link_to);
        s
    }
}

/// Cache key for snapshot reuse: a decision at the same instant, for the
/// same origin, against unmutated tables and suspect set sees the exact
/// same snapshot — rebuilding would produce identical bytes, so reuse is
/// behaviour-preserving by construction. Table/peer versions come from
/// [`ProfileTable::version`] / [`PeerTable::version`] (bumped on every
/// mutation); the suspect-set version is maintained by the owning node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct SnapshotKey {
    now_bits: u64,
    origin: NodeId,
    table_version: u64,
    peers_version: u64,
    suspects_version: u64,
}

// ---------------------------------------------------------------------
// Admit stage.
// ---------------------------------------------------------------------

/// Resolved admission parameters (config `[admission]` + per-app
/// `admit_rate_per_s` overrides — see
/// [`crate::config::SystemConfig::admission_params`]).
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionParams {
    /// Token-bucket rate for apps without an override (frames/second);
    /// `f64::INFINITY` disables rate limiting, leaving only the ceiling.
    pub default_rate_per_s: f64,
    /// Bucket depth (burst tolerance), in tokens.
    pub burst: f64,
    /// Per-app ceiling on frames queued in the edge pool: an arrival that
    /// finds its app's queue at the ceiling is rejected.
    pub queue_ceiling: u32,
    /// Enable the Overload stage's deadline-aware shed of best-effort
    /// frames at enqueue.
    pub deadline_shed: bool,
    /// Per-app rate overrides, `AppId`-indexed (registry order).
    pub per_app_rate: Vec<Option<f64>>,
}

impl AdmissionParams {
    fn rate_for(&self, app: AppId) -> f64 {
        self.per_app_rate
            .get(app.0 as usize)
            .copied()
            .flatten()
            .unwrap_or(self.default_rate_per_s)
    }
}

/// Admit-stage verdict. Both rejection flavours record as
/// [`crate::core::DropReason::Rejected`]; they are split here so tests
/// can tell the two mechanisms apart.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitVerdict {
    /// Within rate and ceiling: the frame proceeds to the Place stage.
    Admit,
    /// Token bucket empty: the app exceeded its admitted rate.
    RejectRate,
    /// The app already has `queue_ceiling` frames queued at the edge.
    RejectQueue,
}

#[derive(Debug, Clone, Copy)]
struct Bucket {
    tokens: f64,
    last_ms: f64,
}

/// Per-app token buckets, refilled continuously on the driver's clock
/// (virtual or wall) — deterministic in virtual mode since refill depends
/// only on event timestamps.
#[derive(Debug, Clone)]
pub struct AdmitStage {
    params: AdmissionParams,
    buckets: BTreeMap<AppId, Bucket>,
}

impl AdmitStage {
    /// Build the stage from resolved admission parameters.
    pub fn new(params: AdmissionParams) -> Self {
        Self { params, buckets: BTreeMap::new() }
    }

    /// Whether the Overload stage’s deadline shed is enabled.
    pub fn deadline_shed(&self) -> bool {
        self.params.deadline_shed
    }

    /// Admit or reject `img`. `queued_for_app` is the app's current depth
    /// in the edge pool's overflow queue. The ceiling is checked first so
    /// a queue-rejected frame does not also consume a token.
    pub fn admit(&mut self, img: &ImageMeta, now_ms: f64, queued_for_app: u32) -> AdmitVerdict {
        if queued_for_app >= self.params.queue_ceiling {
            return AdmitVerdict::RejectQueue;
        }
        let rate = self.params.rate_for(img.constraint.app);
        if rate.is_infinite() {
            return AdmitVerdict::Admit;
        }
        let burst = self.params.burst;
        let b = self
            .buckets
            .entry(img.constraint.app)
            .or_insert(Bucket { tokens: burst, last_ms: now_ms });
        b.tokens = (b.tokens + (now_ms - b.last_ms).max(0.0) * rate / 1_000.0).min(burst);
        b.last_ms = now_ms;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            AdmitVerdict::Admit
        } else {
            AdmitVerdict::RejectRate
        }
    }

    /// Sum of tokens currently banked across the app buckets — the live
    /// introspection gauge. Buckets refill lazily at each admit, so this
    /// reads each app's balance as of its last arrival.
    pub fn tokens_banked(&self) -> f64 {
        self.buckets.values().map(|b| b.tokens).sum()
    }

    /// Churn: a crashed edge loses its admission state with the rest.
    pub fn reset(&mut self) {
        self.buckets.clear();
    }
}

// ---------------------------------------------------------------------
// Overload stage.
// ---------------------------------------------------------------------

/// Deadline-aware shed at enqueue: a *best-effort* frame (priority 0)
/// that would only queue (no idle container) and whose coarse predicted
/// completion already exceeds its deadline is dropped now, before it
/// wastes queue slots and a container on a result nobody can use.
/// Higher-priority frames are never shed — their deadline pressure is
/// what the (priority, EDF) / DRR dispatch order exists to serve.
pub fn should_shed(img: &ImageMeta, pool: &ContainerPool, now_ms: f64) -> bool {
    img.constraint.priority == 0
        && pool.idle_count() == 0
        && pool.predicted_completion_ms(img, now_ms) > img.abs_deadline_ms()
}

// ---------------------------------------------------------------------
// The edge pipeline: Admit state + snapshot cache, owned by EdgeNode.
// ---------------------------------------------------------------------

/// Per-edge pipeline state. `DeviceNode` carries no pipeline struct (it
/// drives the stage *functions* only), though it may hold its own
/// [`AdmitStage`] when `[admission] device_intake = true` pushes the
/// token bucket to the point where frames are born; by default admission
/// guards the cell ingest point alone.
#[derive(Debug, Clone)]
pub struct EdgePipeline {
    admit: Option<AdmitStage>,
    snapshot: CandidateSnapshot,
    cache_key: Option<SnapshotKey>,
    /// Incremental snapshot maintenance (on by default): patch the cached
    /// snapshot forward from the tables' change journals instead of
    /// rebuilding on every version bump. Switched off only by tests that
    /// prove patched and rebuilt runs emit identical action streams.
    incremental: bool,
    /// Lifetime counters for the perf trajectory (BENCH json, tests).
    pub snapshot_rebuilds: u64,
    /// Lifetime count of cache hits (see `snapshot_rebuilds`).
    pub snapshot_reuses: u64,
    /// Lifetime count of incremental patches — version bumps absorbed
    /// without a full table rescan (see `snapshot_rebuilds`).
    pub snapshot_deltas: u64,
    /// Observability hook: `Snapshot{op}` events for every rebuild/delta
    /// (reuses stay silent — they are the steady state). `None` (the
    /// default) emits nothing, so untraced runs take no lock.
    trace: Option<PipelineTrace>,
}

/// The pipeline's slice of a run-wide trace: the shared sink plus the
/// owning edge's id (the pipeline itself doesn't know whose it is).
#[derive(Clone)]
struct PipelineTrace {
    sink: SharedTrace,
    node: NodeId,
}

impl fmt::Debug for PipelineTrace {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PipelineTrace").field("node", &self.node).finish_non_exhaustive()
    }
}

impl EdgePipeline {
    /// Build the pipeline; `None` admission = the legacy no-op stage.
    pub fn new(admission: Option<AdmissionParams>) -> Self {
        Self {
            admit: admission.map(AdmitStage::new),
            snapshot: CandidateSnapshot::new(),
            cache_key: None,
            incremental: true,
            snapshot_rebuilds: 0,
            snapshot_reuses: 0,
            snapshot_deltas: 0,
            trace: None,
        }
    }

    /// Attach a run-wide trace sink; `node` is the owning edge (stamped
    /// into every `Snapshot` event). Survives `reset_on_fail` — churn
    /// resets scheduling state, not observability.
    pub fn set_trace(&mut self, sink: SharedTrace, node: NodeId) {
        self.trace = Some(PipelineTrace { sink, node });
    }

    fn trace_snapshot(&self, now_ms: f64, op: &'static str) {
        if let Some(t) = &self.trace {
            t.sink.lock().unwrap().emit(now_ms, &TraceEvent::Snapshot { node: t.node, op });
        }
    }

    /// Enable/disable incremental snapshot maintenance. With it off every
    /// cache miss is a full rebuild — the twin-test lever proving the
    /// delta path is behaviour-preserving.
    pub fn set_incremental(&mut self, on: bool) {
        self.incremental = on;
    }

    /// Whether an Admit stage is configured at all. Callers gate the
    /// per-app queue-depth lookup on this — under the strict discipline
    /// that lookup is an O(queue) scan, which the legacy path must not
    /// pay for a verdict that would be discarded.
    pub fn admission_enabled(&self) -> bool {
        self.admit.is_some()
    }

    /// Admit stage: `Admit` unconditionally when no `[admission]` section
    /// is configured (the legacy no-op).
    pub fn admit(&mut self, img: &ImageMeta, now_ms: f64, queued_for_app: u32) -> AdmitVerdict {
        match &mut self.admit {
            Some(stage) => stage.admit(img, now_ms, queued_for_app),
            None => AdmitVerdict::Admit,
        }
    }

    /// Whether the Overload stage's deadline shed is enabled.
    pub fn deadline_shed(&self) -> bool {
        self.admit.as_ref().is_some_and(AdmitStage::deadline_shed)
    }

    /// Tokens banked across the Admit stage's app buckets (`None` without
    /// an `[admission]` config) — the introspection gauge.
    pub fn admission_tokens(&self) -> Option<f64> {
        self.admit.as_ref().map(AdmitStage::tokens_banked)
    }

    /// The shared per-decision candidate snapshot, reused verbatim while
    /// nothing it derives from has changed (same instant, same origin,
    /// unmutated tables/suspects) — the `decide_edge` hot-path win. A
    /// changed key first tries an *incremental* patch: same origin and
    /// suspect set, every intervening mutation still in the tables'
    /// bounded change journals, and no structural change — then only the
    /// touched entries (plus the staleness flags, if the instant moved)
    /// are re-resolved. Anything else falls back to a full rebuild, so
    /// the snapshot is always byte-identical to a fresh one.
    #[allow(clippy::too_many_arguments)]
    pub fn prepare(
        &mut self,
        table: &ProfileTable,
        peers: &PeerTable,
        suspects: &BTreeSet<NodeId>,
        suspects_version: u64,
        links: &[Option<LinkModel>],
        origin: NodeId,
        now_ms: f64,
        max_staleness_ms: f64,
    ) -> &CandidateSnapshot {
        let key = SnapshotKey {
            now_bits: now_ms.to_bits(),
            origin,
            table_version: table.version(),
            peers_version: peers.version(),
            suspects_version,
        };
        if self.cache_key == Some(key) {
            self.snapshot_reuses += 1;
            return &self.snapshot;
        }
        let patched = match self.cache_key {
            Some(old)
                if self.incremental
                    && old.origin == key.origin
                    && old.suspects_version == key.suspects_version =>
            {
                self.try_patch(&old, table, peers, suspects, links, origin, now_ms, max_staleness_ms)
            }
            _ => false,
        };
        if patched {
            self.snapshot_deltas += 1;
            self.trace_snapshot(now_ms, "delta");
        } else {
            self.snapshot.rebuild(table, peers, suspects, origin, now_ms, max_staleness_ms, |n| {
                links.get(n.0 as usize).copied().flatten()
            });
            self.snapshot_rebuilds += 1;
            self.trace_snapshot(now_ms, "rebuild");
        }
        self.cache_key = Some(key);
        &self.snapshot
    }

    /// Patch the cached snapshot forward from `old` to the tables' current
    /// versions. `false` (journal scrolled, or a structural change) means
    /// the caller must rebuild — a partially patched snapshot is then
    /// overwritten wholesale, so bailing mid-way is safe.
    #[allow(clippy::too_many_arguments)]
    fn try_patch(
        &mut self,
        old: &SnapshotKey,
        table: &ProfileTable,
        peers: &PeerTable,
        suspects: &BTreeSet<NodeId>,
        links: &[Option<LinkModel>],
        origin: NodeId,
        now_ms: f64,
        max_staleness_ms: f64,
    ) -> bool {
        let link_to = |n: NodeId| links.get(n.0 as usize).copied().flatten();
        let Some(dev_changes) = table.changes_since(old.table_version) else { return false };
        let Some(peer_changes) = peers.changes_since(old.peers_version) else { return false };
        if old.now_bits != now_ms.to_bits() {
            self.snapshot.refresh_staleness(now_ms, max_staleness_ms);
        }
        for node in dev_changes {
            if !self.snapshot.patch_device(
                node,
                table,
                suspects,
                origin,
                now_ms,
                max_staleness_ms,
                link_to,
            ) {
                return false;
            }
        }
        for edge in peer_changes {
            if !self
                .snapshot
                .patch_peer(edge, peers, suspects, now_ms, max_staleness_ms, link_to)
            {
                return false;
            }
        }
        true
    }

    /// Drop the cached snapshot (and key). Called on churn `fail()` —
    /// replacing the tables resets their version counters, which could
    /// otherwise collide with a pre-fail key.
    pub fn invalidate(&mut self) {
        self.cache_key = None;
    }

    /// Churn: crash semantics for the whole pipeline state.
    pub fn reset_on_fail(&mut self) {
        self.invalidate();
        if let Some(a) = &mut self.admit {
            a.reset();
        }
    }
}

// ---------------------------------------------------------------------
// Stage timing (opt-in; wall clock — never part of the replay surface).
// ---------------------------------------------------------------------

/// Per-stage wall-clock histograms (`--stage-timing`, nanoseconds).
/// Wall time is nondeterministic by nature, so these live only in
/// [`crate::sim::RunReport`]'s gated `stage_ns` side channel — never in
/// `RunSummary`, which determinism tests compare whole (DESIGN.md
/// §Observability).
#[derive(Debug, Clone, Default)]
pub struct StageTimers {
    /// Admit stage: token-bucket + ceiling ruling per fresh arrival.
    pub admit: Hist,
    /// Place stage: candidate-snapshot prepare + the policy's edge-level
    /// decision (the scheduling hot path the snapshot cache exists for).
    pub place: Hist,
    /// Dispatch stage: local pool submit/enqueue for frames placed here.
    pub dispatch: Hist,
}

impl StageTimers {
    /// Fold another edge's timers into this one (run-wide aggregation).
    pub fn merge(&mut self, other: &StageTimers) {
        self.admit.merge(&other.admit);
        self.place.merge(&other.place);
        self.dispatch.merge(&other.dispatch);
    }

    /// Whether any stage recorded a sample.
    pub fn is_empty(&self) -> bool {
        self.admit.is_empty() && self.place.is_empty() && self.dispatch.is_empty()
    }

    /// Hand-rolled JSON object keyed by stage (see [`Hist::json`]).
    pub fn json(&self) -> String {
        format!(
            r#"{{"admit":{},"place":{},"dispatch":{}}}"#,
            self.admit.json(),
            self.place.json(),
            self.dispatch.json()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Constraint, NodeClass, TaskId};
    use crate::profile::profile_for;

    fn img(task: u64, app: u16, priority: u8, deadline: f64, created: f64) -> ImageMeta {
        ImageMeta {
            task: TaskId(task),
            origin: NodeId(1),
            size_kb: 29.0,
            side_px: 64,
            created_ms: created,
            constraint: Constraint::for_app(AppId(app), deadline, PrivacyClass::Open, priority),
            seq: task,
        }
    }

    fn params(rate: f64, burst: f64, ceiling: u32, shed: bool) -> AdmissionParams {
        AdmissionParams {
            default_rate_per_s: rate,
            burst,
            queue_ceiling: ceiling,
            deadline_shed: shed,
            per_app_rate: Vec::new(),
        }
    }

    #[test]
    fn device_intake_clamps() {
        assert_eq!(
            device_intake(PrivacyClass::DeviceLocal, false),
            DeviceIntake::ClampLocal { infeasible: false }
        );
        assert_eq!(
            device_intake(PrivacyClass::DeviceLocal, true),
            DeviceIntake::ClampLocal { infeasible: true }
        );
        assert_eq!(device_intake(PrivacyClass::Open, true), DeviceIntake::ForceForward);
        assert_eq!(device_intake(PrivacyClass::CellLocal, false), DeviceIntake::Place);
    }

    #[test]
    fn edge_intake_and_clamp() {
        assert_eq!(edge_intake(PrivacyClass::DeviceLocal), EdgeIntake::ReturnToOrigin);
        assert_eq!(edge_intake(PrivacyClass::CellLocal), EdgeIntake::Schedule);
        assert_eq!(
            clamp_placement(PrivacyClass::CellLocal, Placement::ToPeerEdge(NodeId(3))),
            Placement::Local
        );
        assert_eq!(
            clamp_placement(PrivacyClass::Open, Placement::ToPeerEdge(NodeId(3))),
            Placement::ToPeerEdge(NodeId(3))
        );
        assert_eq!(
            clamp_placement(PrivacyClass::CellLocal, Placement::Offload(NodeId(2))),
            Placement::Offload(NodeId(2))
        );
        // The cloud uplink is open-only: both constrained classes clamp.
        assert_eq!(
            clamp_placement(PrivacyClass::CellLocal, Placement::ToCloud(NodeId(9))),
            Placement::Local
        );
        assert_eq!(
            clamp_placement(PrivacyClass::DeviceLocal, Placement::ToCloud(NodeId(9))),
            Placement::Local
        );
        assert_eq!(
            clamp_placement(PrivacyClass::Open, Placement::ToCloud(NodeId(9))),
            Placement::ToCloud(NodeId(9))
        );
    }

    #[test]
    fn token_bucket_rate_limits_and_refills() {
        let mut s = AdmitStage::new(params(10.0, 2.0, 100, false));
        // Burst of 2 admits, third rejects.
        assert_eq!(s.admit(&img(1, 0, 0, 1e4, 0.0), 0.0, 0), AdmitVerdict::Admit);
        assert_eq!(s.admit(&img(2, 0, 0, 1e4, 0.0), 0.0, 0), AdmitVerdict::Admit);
        assert_eq!(s.admit(&img(3, 0, 0, 1e4, 0.0), 0.0, 0), AdmitVerdict::RejectRate);
        // 100 ms at 10/s refills one token.
        assert_eq!(s.admit(&img(4, 0, 0, 1e4, 100.0), 100.0, 0), AdmitVerdict::Admit);
        assert_eq!(s.admit(&img(5, 0, 0, 1e4, 100.0), 100.0, 0), AdmitVerdict::RejectRate);
        // Refill caps at the burst depth.
        assert_eq!(s.admit(&img(6, 0, 0, 1e4, 1e6), 1e6, 0), AdmitVerdict::Admit);
        assert_eq!(s.admit(&img(7, 0, 0, 1e4, 1e6), 1e6, 0), AdmitVerdict::Admit);
        assert_eq!(s.admit(&img(8, 0, 0, 1e4, 1e6), 1e6, 0), AdmitVerdict::RejectRate);
    }

    #[test]
    fn queue_ceiling_rejects_before_consuming_tokens() {
        let mut s = AdmitStage::new(params(10.0, 1.0, 2, false));
        assert_eq!(s.admit(&img(1, 0, 0, 1e4, 0.0), 0.0, 2), AdmitVerdict::RejectQueue);
        // The bucket was untouched: the next under-ceiling frame admits.
        assert_eq!(s.admit(&img(2, 0, 0, 1e4, 0.0), 0.0, 1), AdmitVerdict::Admit);
    }

    #[test]
    fn buckets_are_per_app() {
        let mut s = AdmitStage::new(params(1.0, 1.0, 100, false));
        assert_eq!(s.admit(&img(1, 0, 0, 1e4, 0.0), 0.0, 0), AdmitVerdict::Admit);
        assert_eq!(s.admit(&img(2, 0, 0, 1e4, 0.0), 0.0, 0), AdmitVerdict::RejectRate);
        // App 1 has its own bucket.
        assert_eq!(s.admit(&img(3, 1, 0, 1e4, 0.0), 0.0, 0), AdmitVerdict::Admit);
    }

    #[test]
    fn per_app_rate_override_wins() {
        let mut p = params(f64::INFINITY, 1.0, 100, false);
        p.per_app_rate = vec![None, Some(1.0)];
        let mut s = AdmitStage::new(p);
        // App 0: default infinite rate — always admitted.
        for t in 0..10 {
            assert_eq!(s.admit(&img(t, 0, 0, 1e4, 0.0), 0.0, 0), AdmitVerdict::Admit);
        }
        // App 1: 1/s with burst 1 — second frame at t=0 rejects.
        assert_eq!(s.admit(&img(20, 1, 0, 1e4, 0.0), 0.0, 0), AdmitVerdict::Admit);
        assert_eq!(s.admit(&img(21, 1, 0, 1e4, 0.0), 0.0, 0), AdmitVerdict::RejectRate);
    }

    #[test]
    fn pipeline_without_admission_admits_everything() {
        let mut p = EdgePipeline::new(None);
        for t in 0..100 {
            assert_eq!(p.admit(&img(t, 0, 0, 1.0, 0.0), 0.0, u32::MAX - 1), AdmitVerdict::Admit);
        }
        assert!(!p.deadline_shed());
    }

    #[test]
    fn shed_only_hopeless_best_effort_with_no_idle_container() {
        let mut pool = ContainerPool::new(profile_for(NodeClass::EdgeServer), 1);
        let hopeless = img(90, 0, 0, 50.0, 0.0); // 50 ms budget, ~223 ms process
        // Idle container available: never shed, regardless of deadline.
        assert!(!should_shed(&hopeless, &pool, 0.0));
        pool.submit(img(1, 0, 0, 1e6, 0.0), 0.0).unwrap();
        // Saturated + hopeless + priority 0 → shed.
        assert!(should_shed(&hopeless, &pool, 0.0));
        // Same frame at priority 1 is never shed.
        let strict = img(91, 0, 1, 50.0, 0.0);
        assert!(!should_shed(&strict, &pool, 0.0));
        // Generous deadline → not shed.
        let ok = img(92, 0, 0, 1e6, 0.0);
        assert!(!should_shed(&ok, &pool, 0.0));
    }

    #[test]
    fn snapshot_reuse_and_invalidation() {
        use crate::core::message::ProfileUpdate;
        let mut table = ProfileTable::new();
        table.register(NodeId(2), NodeClass::RaspberryPi, 2, 0.0);
        let peers = PeerTable::new();
        let suspects = BTreeSet::new();
        let links = vec![None, Some(LinkModel::wifi()), Some(LinkModel::wifi())];
        let mut p = EdgePipeline::new(None);
        let n =
            p.prepare(&table, &peers, &suspects, 0, &links, NodeId(1), 5.0, 200.0).devices().len();
        assert_eq!(n, 1);
        assert_eq!((p.snapshot_rebuilds, p.snapshot_reuses), (1, 0));
        // Identical inputs → cache hit.
        p.prepare(&table, &peers, &suspects, 0, &links, NodeId(1), 5.0, 200.0);
        assert_eq!((p.snapshot_rebuilds, p.snapshot_reuses), (1, 1));
        // Different origin → full rebuild (the exclusion set changed).
        p.prepare(&table, &peers, &suspects, 0, &links, NodeId(3), 5.0, 200.0);
        assert_eq!(p.snapshot_rebuilds, 2);
        // In-place table mutation (UP push) → incremental patch, and the
        // patched entry carries the new state.
        table.apply(&ProfileUpdate {
            node: NodeId(2),
            busy_containers: 1,
            warm_containers: 2,
            queued_images: 0,
            cpu_load_pct: 0.0,
            battery_pct: None,
            sent_ms: 6.0,
        });
        let s = p.prepare(&table, &peers, &suspects, 0, &links, NodeId(3), 5.0, 200.0);
        assert_eq!(s.devices()[0].state.busy_containers, 1);
        assert_eq!((p.snapshot_rebuilds, p.snapshot_deltas), (2, 1));
        // Suspects version bump → rebuild; explicit invalidate → rebuild.
        p.prepare(&table, &peers, &suspects, 1, &links, NodeId(3), 5.0, 200.0);
        assert_eq!(p.snapshot_rebuilds, 3);
        p.invalidate();
        p.prepare(&table, &peers, &suspects, 1, &links, NodeId(3), 5.0, 200.0);
        assert_eq!(p.snapshot_rebuilds, 4);
        // A structural change (new registration) cannot be patched in.
        table.register(NodeId(2), NodeClass::RaspberryPi, 2, 0.0); // re-register: in place
        p.prepare(&table, &peers, &suspects, 1, &links, NodeId(3), 6.0, 200.0);
        assert_eq!((p.snapshot_rebuilds, p.snapshot_deltas), (4, 2));
        table.deregister(NodeId(2));
        p.prepare(&table, &peers, &suspects, 1, &links, NodeId(3), 6.0, 200.0);
        assert_eq!(p.snapshot_rebuilds, 5);
        // With incremental maintenance off, every miss is a rebuild.
        p.set_incremental(false);
        table.register(NodeId(2), NodeClass::RaspberryPi, 2, 0.0);
        table.apply(&ProfileUpdate {
            node: NodeId(2),
            busy_containers: 0,
            warm_containers: 2,
            queued_images: 0,
            cpu_load_pct: 0.0,
            battery_pct: None,
            sent_ms: 7.0,
        });
        p.prepare(&table, &peers, &suspects, 1, &links, NodeId(3), 7.0, 200.0);
        table.apply(&ProfileUpdate {
            node: NodeId(2),
            busy_containers: 1,
            warm_containers: 2,
            queued_images: 0,
            cpu_load_pct: 0.0,
            battery_pct: None,
            sent_ms: 8.0,
        });
        p.prepare(&table, &peers, &suspects, 1, &links, NodeId(3), 8.0, 200.0);
        assert_eq!((p.snapshot_rebuilds, p.snapshot_deltas), (7, 2));
    }

    #[test]
    fn patched_snapshot_equals_fresh_rebuild_under_churny_mutations() {
        use crate::core::message::{EdgeSummary, ProfileUpdate};
        let up = |node: u32, busy: u32, sent: f64| ProfileUpdate {
            node: NodeId(node),
            busy_containers: busy,
            warm_containers: 2,
            queued_images: busy,
            cpu_load_pct: 5.0 * busy as f64,
            battery_pct: None,
            sent_ms: sent,
        };
        let summary = |edge: u32, busy: u32, sent: f64, hops: u8, via: u32| EdgeSummary {
            edge: NodeId(edge),
            busy_containers: busy,
            warm_containers: 4,
            queued_images: 0,
            cpu_load_pct: 0.0,
            device_idle_containers: 2,
            sent_ms: sent,
            hops,
            via: NodeId(via),
        };
        let mut table = ProfileTable::new();
        for n in [2u32, 3, 4] {
            table.register(NodeId(n), NodeClass::RaspberryPi, 2, 0.0);
        }
        let mut peers = PeerTable::new();
        peers.apply(&summary(9, 0, 0.0, 0, 9));
        peers.apply(&summary(10, 0, 0.0, 1, 9));
        let suspects = BTreeSet::new();
        // Links for devices 2..4 and next hop 9; subject 10 is link-less
        // (reachable only via 9) and node 4 is link-less entirely.
        let mut links = vec![None; 11];
        for n in [2usize, 3, 9] {
            links[n] = Some(LinkModel::wifi());
        }
        let mut p = EdgePipeline::new(None);
        p.prepare(&table, &peers, &suspects, 0, &links, NodeId(1), 10.0, 200.0);

        // A churn-flavoured mutation burst: UP pushes, gossip refreshes,
        // an optimistic bump, a stale-by-now device, and a time step —
        // everything short of membership change.
        table.apply(&up(2, 1, 20.0));
        table.apply(&up(3, 2, 25.0));
        table.apply(&up(4, 1, 25.0)); // link-less: patch is a no-op
        table.apply(&up(7, 1, 25.0)); // unregistered sender: ignored
        peers.apply(&summary(9, 3, 30.0, 0, 9));
        peers.apply(&summary(10, 1, 28.0, 1, 9));
        peers.bump_busy(NodeId(9));
        let now = 240.0; // device 2's 20.0 push is now stale (cap 200)
        let patched =
            p.prepare(&table, &peers, &suspects, 0, &links, NodeId(1), now, 200.0).clone();
        assert_eq!(p.snapshot_deltas, 1, "the burst must patch, not rebuild");
        let fresh = CandidateSnapshot::build(&table, &peers, &suspects, NodeId(1), now, 200.0, |n| {
            links.get(n.0 as usize).copied().flatten()
        });
        assert_eq!(patched.devices(), fresh.devices());
        assert_eq!(patched.peers(), fresh.peers());
        assert!(!patched.devices()[0].fresh, "device 2 must have gone stale");
        assert_eq!(patched.peers()[0].state.busy_containers, 4, "bump visible");
    }

    #[test]
    fn scrolled_change_journal_forces_rebuild() {
        use crate::core::message::ProfileUpdate;
        let mut table = ProfileTable::new();
        table.register(NodeId(2), NodeClass::RaspberryPi, 2, 0.0);
        let peers = PeerTable::new();
        let suspects = BTreeSet::new();
        let links = vec![None, None, Some(LinkModel::wifi())];
        let mut p = EdgePipeline::new(None);
        p.prepare(&table, &peers, &suspects, 0, &links, NodeId(1), 5.0, 200.0);
        // Push the journal far past its window.
        for i in 0..200u32 {
            table.apply(&ProfileUpdate {
                node: NodeId(2),
                busy_containers: i % 2,
                warm_containers: 2,
                queued_images: 0,
                cpu_load_pct: 0.0,
                battery_pct: None,
                sent_ms: 5.0 + i as f64,
            });
        }
        p.prepare(&table, &peers, &suspects, 0, &links, NodeId(1), 6.0, 200.0);
        assert_eq!((p.snapshot_rebuilds, p.snapshot_deltas), (2, 0));
    }

    #[test]
    fn snapshot_excludes_origin_and_linkless_keeps_stale_flagged() {
        use crate::core::message::{EdgeSummary, ProfileUpdate};
        let mut table = ProfileTable::new();
        for n in [1u32, 2, 3, 4] {
            table.register(NodeId(n), NodeClass::RaspberryPi, 2, 0.0);
        }
        // n2 fresh, n3 stale, n4 link-less.
        for (n, sent) in [(2u32, 100.0), (3, -1_000.0), (4, 100.0)] {
            table.apply(&ProfileUpdate {
                node: NodeId(n),
                busy_containers: 0,
                warm_containers: 2,
                queued_images: 0,
                cpu_load_pct: 0.0,
                battery_pct: None,
                sent_ms: sent,
            });
        }
        let mut peers = PeerTable::new();
        peers.apply(&EdgeSummary {
            edge: NodeId(9),
            busy_containers: 0,
            warm_containers: 4,
            queued_images: 0,
            cpu_load_pct: 0.0,
            device_idle_containers: 0,
            sent_ms: 100.0,
            hops: 0,
            via: NodeId(9),
        });
        let mut suspects = BTreeSet::new();
        suspects.insert(NodeId(2));
        let link = |n: NodeId| (n != NodeId(4)).then(LinkModel::wifi);
        let s =
            CandidateSnapshot::build(&table, &peers, &suspects, NodeId(1), 110.0, 200.0, link);
        // Origin (1) and link-less (4) excluded; stale (3) kept, flagged.
        let nodes: Vec<u32> = s.devices().iter().map(|c| c.state.node.0).collect();
        assert_eq!(nodes, vec![2, 3]);
        assert!(s.devices()[0].fresh && s.devices()[0].suspect);
        assert!(!s.devices()[1].fresh && !s.devices()[1].suspect);
        assert_eq!(s.peers().len(), 1);
        assert!(s.peers()[0].fresh && !s.peers()[0].suspect);
    }
}
