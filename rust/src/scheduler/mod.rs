//! Scheduling policies — the paper's DDS and its comparison groups —
//! and the staged scheduling pipeline they are the Place stage of.
//!
//! Policies are *pure decision logic* shared verbatim by the discrete-event
//! simulator and the live socket deployment: both construct the same
//! [`DeviceCtx`]/[`EdgeCtx`] views and call the same `decide_*` methods.
//! The per-frame decision path around them is the explicit stage sequence
//! `Admit → Filter → Place → Dispatch → Overload` (see [`pipeline`] and
//! DESIGN.md §3); the edge-level context carries a
//! [`pipeline::CandidateSnapshot`] — the MP and peer tables resolved once
//! per decision — instead of raw table references.
//!
//! Three decision points — the paper's two levels plus the federation
//! extension (DESIGN.md §Federation):
//! - **device-level** (APr decision thread): keep the image local or
//!   forward it to the edge server;
//! - **edge-level** (APe decision thread): run in the edge pool or offload
//!   to another end device in the same cell;
//! - **federation-level** (edge, multi-cell deployments): when the cell is
//!   exhausted, forward the image over the backhaul to a peer edge server
//!   chosen from gossiped MP summaries. Only the DDS family federates;
//!   the comparison baselines never return `ToPeerEdge`.

pub mod pipeline;
pub mod policies;

use anyhow::{bail, Result};

pub use pipeline::{AdmissionParams, AdmitVerdict, CandidateSnapshot, EdgePipeline, StageTimers};
pub use policies::{Aoe, Aor, Dds, DdsEnergy, DdsNoAvail, Eods, RandomPolicy, RoundRobin};

use crate::core::{ImageMeta, NodeClass, NodeId, Placement};
use crate::net::LinkModel;
use crate::profile::{profile_for, Predictor};
use crate::util::SplitMix64;

/// Battery reserve below which [`DdsEnergy`] conserves energy (percent).
pub const DEFAULT_ENERGY_RESERVE_PCT: f64 = 20.0;

/// Heartbeat-based failure-detection thresholds (DESIGN.md §Churn).
///
/// A node whose heartbeat (UP push for a device, MP-summary gossip for a
/// peer edge, [`crate::core::Message::Ping`] for the edge as seen by its
/// devices) has been silent longer than `suspect_after_ms` is *suspected* —
/// the scheduler stops targeting it but keeps its state. Silence beyond
/// `dead_after_ms` declares it *dead*: its table entry is evicted and every
/// in-flight frame placed on it is requeued and re-placed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureDetector {
    /// Heartbeat silence (ms) after which a node is suspected.
    pub suspect_after_ms: f64,
    /// Heartbeat silence (ms) after which a node is declared dead.
    pub dead_after_ms: f64,
}

/// Predictors for every hardware class (built once, shared by contexts).
#[derive(Debug, Clone)]
pub struct PredictorSet {
    edge: Predictor,
    rpi: Predictor,
    phone: Predictor,
    cloud: Predictor,
}

impl PredictorSet {
    /// Build the per-class predictors from the paper’s profiles.
    pub fn new() -> Self {
        PredictorSet {
            edge: Predictor::new(profile_for(NodeClass::EdgeServer)),
            rpi: Predictor::new(profile_for(NodeClass::RaspberryPi)),
            phone: Predictor::new(profile_for(NodeClass::SmartPhone)),
            cloud: Predictor::new(profile_for(NodeClass::CloudServer)),
        }
    }

    /// The predictor for one hardware class.
    pub fn for_class(&self, class: NodeClass) -> &Predictor {
        match class {
            NodeClass::EdgeServer => &self.edge,
            NodeClass::RaspberryPi => &self.rpi,
            NodeClass::SmartPhone => &self.phone,
            NodeClass::CloudServer => &self.cloud,
        }
    }
}

impl Default for PredictorSet {
    fn default() -> Self {
        Self::new()
    }
}

/// Snapshot of the *local* node for a device-level decision.
#[derive(Debug, Clone, Copy)]
pub struct LocalSnapshot {
    /// The node this snapshot describes.
    pub node: NodeId,
    /// Containers currently executing.
    pub busy_containers: u32,
    /// Warm containers (busy + idle).
    pub warm_containers: u32,
    /// Locally queued images.
    pub queued_images: u32,
    /// Background CPU load in [0, 100].
    pub cpu_load_pct: f64,
    /// Remaining battery [0, 100]; `None` for mains-powered nodes.
    pub battery_pct: Option<f64>,
}

/// Context for the device-level decision.
pub struct DeviceCtx<'a> {
    /// Decision instant on the run clock (ms).
    pub now_ms: f64,
    /// The frame being decided.
    pub img: &'a ImageMeta,
    /// The deciding device’s own state.
    pub local: LocalSnapshot,
    /// Predictor for the local node's hardware class.
    pub predictor: &'a Predictor,
    /// The device's failure detector suspects its edge server is down
    /// (no ping/result heard for longer than the dead threshold). The DDS
    /// family keeps frames local rather than sending them into the void;
    /// baselines ignore it. Always `false` when churn detection is off.
    pub edge_suspected: bool,
}

impl DeviceCtx<'_> {
    /// Deadline budget still available at decision time.
    pub fn remaining_ms(&self) -> f64 {
        self.img.constraint.deadline_ms - (self.now_ms - self.img.created_ms)
    }
}

/// Context for the edge-level decision.
pub struct EdgeCtx<'a> {
    /// Decision instant on the run clock (ms).
    pub now_ms: f64,
    /// The frame being decided.
    pub img: &'a ImageMeta,
    /// The deciding edge server’s own state.
    pub edge: LocalSnapshot,
    /// Per-class predictors (edge's own class + offload candidates).
    pub predictors: &'a PredictorSet,
    /// The Filter stage's candidate snapshot: MP and peer tables resolved
    /// once per decision — staleness, failure-detector suspicion, and
    /// links — in deterministic registration order with the frame's
    /// origin excluded (DESIGN.md §3). Policies read this instead of
    /// re-scanning the tables per level.
    pub candidates: &'a CandidateSnapshot,
    /// The image already crossed a backhaul at least once: its placement
    /// record belongs to the originating edge, and the Overload stage
    /// exempts it from shedding (the previous hop owes a Result upstream).
    pub forwarded: bool,
    /// Remaining backhaul-hop budget for this frame (hierarchical
    /// routing, DESIGN.md §Hierarchical routing): `[federation]
    /// max_forward_hops` for fresh frames, the decremented `ForwardRoute`
    /// TTL for forwarded ones. The federation level only returns
    /// `ToPeerEdge` when this is ≥ 1 — and never picks a subject whose
    /// route needs more hops than remain.
    pub hops_left: u8,
    /// Edges the frame has already visited (loop protection): neither a
    /// visited subject nor a visited next hop is a candidate.
    pub visited: &'a [NodeId],
    /// Weighted-fair share of the frame's app (`[[app]] weight`, 1 when
    /// unset): the federation level scores peers by advertised queue
    /// depth ÷ this weight, so heavier tenants tolerate deeper remote
    /// queues before a cell is ruled out.
    pub app_weight: u32,
    /// The elastic cloud tier behind this edge's WAN uplink, when one is
    /// configured (DESIGN.md §4e). `None` — the legacy shape — keeps every
    /// policy cloud-blind: the tier level never fires. Static for the
    /// whole run (the cloud neither gossips nor churns), so it lives
    /// outside the candidate snapshot's cache machinery.
    pub cloud: Option<CloudCandidate>,
}

/// The edge's static view of the cloud tier: the node to address and the
/// uplink to cost offloads with (DESIGN.md §4e).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CloudCandidate {
    /// The cloud node's identity.
    pub node: NodeId,
    /// The WAN uplink between this edge and the cloud.
    pub uplink: LinkModel,
}

impl EdgeCtx<'_> {
    /// Deadline budget still available at decision time.
    pub fn remaining_ms(&self) -> f64 {
        self.img.constraint.deadline_ms - (self.now_ms - self.img.created_ms)
    }
}

/// A scheduling policy. Implementations must be deterministic given their
/// seed (reproducible experiments).
pub trait SchedulerPolicy: Send {
    /// Name used in reports.
    fn name(&self) -> &'static str;

    /// Device-level decision: `Local` or `ToEdge` (returning `Offload` here
    /// is a contract violation — devices cannot talk to each other
    /// directly in the star topology).
    fn decide_device(&mut self, ctx: &DeviceCtx) -> Placement;

    /// Edge-level decision: `Local` (edge pool), `Offload(device)`, or —
    /// federation-capable policies only, and only while `ctx.hops_left`
    /// permits — `ToPeerEdge(edge)` to shed the task to a (possibly
    /// multi-hop) peer cell.
    fn decide_edge(&mut self, ctx: &EdgeCtx) -> Placement;

    /// Whether the policy reacts to churn signals (edge suspicion, device-
    /// side requeue of frames awaiting a dead edge — DESIGN.md §Churn).
    /// Baselines are churn-blind by design: that contrast is what the
    /// churn experiments measure.
    fn churn_aware(&self) -> bool {
        false
    }
}

/// Policy selector (config string → constructor).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// All-On-Raspberry-Pi: never leave the origin device.
    Aor,
    /// All-On-Edge: every image goes to the edge server.
    Aoe,
    /// Even-Odd Distributed Scheduling: static parity split.
    Eods,
    /// The paper's Dynamic Distributed Scheduler.
    Dds,
    /// Ablation: DDS without the idle-container availability check.
    DdsNoAvail,
    /// Extension (paper §VI future work): DDS with battery awareness —
    /// low-battery devices conserve energy and are skipped as offload
    /// targets.
    DdsEnergy,
    /// Ablation baseline: alternate local/edge ignoring profiles.
    RoundRobin,
    /// Ablation baseline: uniformly random placement.
    Random,
}

impl PolicyKind {
    /// Parse a policy name (config/CLI spelling).
    pub fn parse(s: &str) -> Result<PolicyKind> {
        Ok(match s {
            "aor" => PolicyKind::Aor,
            "aoe" => PolicyKind::Aoe,
            "eods" => PolicyKind::Eods,
            "dds" => PolicyKind::Dds,
            "dds-no-avail" => PolicyKind::DdsNoAvail,
            "dds-energy" => PolicyKind::DdsEnergy,
            "round-robin" => PolicyKind::RoundRobin,
            "random" => PolicyKind::Random,
            other => bail!("unknown policy `{other}`"),
        })
    }

    /// Stable config/CLI spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            PolicyKind::Aor => "aor",
            PolicyKind::Aoe => "aoe",
            PolicyKind::Eods => "eods",
            PolicyKind::Dds => "dds",
            PolicyKind::DdsNoAvail => "dds-no-avail",
            PolicyKind::DdsEnergy => "dds-energy",
            PolicyKind::RoundRobin => "round-robin",
            PolicyKind::Random => "random",
        }
    }

    /// Instantiate. `seed` only matters for randomized policies.
    pub fn build(&self, seed: u64) -> Box<dyn SchedulerPolicy> {
        match self {
            PolicyKind::Aor => Box::new(Aor),
            PolicyKind::Aoe => Box::new(Aoe),
            PolicyKind::Eods => Box::new(Eods),
            PolicyKind::Dds => Box::new(Dds::new()),
            PolicyKind::DdsNoAvail => Box::new(DdsNoAvail::new()),
            PolicyKind::DdsEnergy => Box::new(DdsEnergy::new(DEFAULT_ENERGY_RESERVE_PCT)),
            PolicyKind::RoundRobin => Box::new(RoundRobin::default()),
            PolicyKind::Random => Box::new(RandomPolicy::new(SplitMix64::new(seed))),
        }
    }

    /// All policy kinds (sweeps).
    pub const ALL: [PolicyKind; 8] = [
        PolicyKind::Aor,
        PolicyKind::Aoe,
        PolicyKind::Eods,
        PolicyKind::Dds,
        PolicyKind::DdsNoAvail,
        PolicyKind::DdsEnergy,
        PolicyKind::RoundRobin,
        PolicyKind::Random,
    ];

    /// The paper's four comparison groups (Figs. 5/6).
    pub const PAPER: [PolicyKind; 4] =
        [PolicyKind::Aor, PolicyKind::Aoe, PolicyKind::Eods, PolicyKind::Dds];
}

impl std::fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for k in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(PolicyKind::parse("nope").is_err());
    }

    #[test]
    fn build_names_match() {
        for k in PolicyKind::ALL {
            let p = k.build(1);
            assert_eq!(p.name(), k.as_str());
        }
    }

    #[test]
    fn paper_subset() {
        assert_eq!(PolicyKind::PAPER.len(), 4);
        assert!(PolicyKind::PAPER.contains(&PolicyKind::Dds));
    }
}
