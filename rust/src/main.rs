//! edge-dds launcher.
//!
//! Subcommands (hand-rolled parser — clap is not in the offline crate set):
//!
//! ```text
//! edge-dds sim    [--config cfg.toml] [--policy dds] [--images N]
//!                 [--interval MS] [--deadline MS] [--seed S] [--csv out.csv]
//! edge-dds sweep  [--config cfg.toml] [--images N] [--interval MS]
//!                 [--deadline MS]                  # all paper policies
//! edge-dds repro  --exp table2|table3|table4|table5|table6|fig5|fig6|fig7|fig8|
//!                       fed|churn|churnsweep|slo|overload|gossip|city|all
//! edge-dds live   [--artifacts DIR] [--policy dds] [--images N]
//!                 [--interval MS] [--deadline MS] [--side PX]
//! ```
//!
//! Multi-cell federations are configured with `[[cell]]` tables plus a
//! per-device `cell = N` key and an optional `[federation]` section
//! (backhaul link + gossip period); see DESIGN.md §Federation. Both `sim`
//! and `live` drive them.
//!
//! Churn & failure injection (DESIGN.md §Churn): `[[churn]]` events
//! (`at_ms`, `kind = "fail"|"recover"|"join"`, `device = i` or
//! `cell = c`), optional seeded `[churn_random]` rates, and `[failure]`
//! detector thresholds. `repro --exp churn` compares deadline satisfaction
//! of DDS vs. the baselines under device churn, edge failure, and mid-run
//! cell join across 1/2/4 cells; `repro --exp churnsweep` plots met
//! fraction against the `[churn_random]` MTBF.
//!
//! Overload control (DESIGN.md §3): the `[admission]` section (per-app
//! token-bucket rate + queue ceiling + `deadline_shed`) and `[[app]]`
//! `weight` keys (weighted-fair DRR dispatch) drive the pipeline's
//! Admit/Dispatch/Overload stages; `repro --exp overload` sweeps arrival
//! rate past saturation comparing strict priority vs. admission+fair.

use std::collections::HashMap;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use edge_dds::config::{RunMode, SystemConfig};
use edge_dds::experiments;
use edge_dds::live::LiveCluster;
use edge_dds::metrics::{write_csv, writer::summary_json};
use edge_dds::runtime::RuntimeService;
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::ScenarioBuilder;

fn main() {
    edge_dds::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "sim" => cmd_sim(&flags),
        "sweep" => cmd_sweep(&flags),
        "repro" => cmd_repro(&flags),
        "live" => cmd_live(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `edge-dds help`)"),
    }
}

fn print_usage() {
    println!(
        "edge-dds — Dynamic Distributed Scheduler for Computing on the Edge\n\
         \n\
         USAGE:\n\
         \x20 edge-dds sim    [--config F] [--policy P] [--images N] [--interval MS]\n\
         \x20                 [--deadline MS] [--seed S] [--csv OUT]\n\
         \x20 edge-dds sweep  [--config F] [--images N] [--interval MS] [--deadline MS]\n\
         \x20 edge-dds repro  --exp table2..table6|fig5..fig8|fed|churn|churnsweep|slo|overload|gossip|city|all\n\
         \x20                 [--images N] [--cells N]   # city/gossip/overload/slo scale knobs\n\
         \x20 edge-dds live   [--artifacts DIR] [--policy P] [--images N]\n\
         \x20                 [--interval MS] [--deadline MS] [--side PX]\n\
         \n\
         POLICIES: aor aoe eods dds dds-no-avail dds-energy round-robin random\n\
         FEDERATION: [[cell]] tables + device `cell = N` + [federation] in --config\n\
         \x20           (topology = mesh|line|ring|tree|hier[:N], max_forward_hops = N)\n\
         CHURN: [[churn]] events + [churn_random] + [failure] thresholds in --config\n\
         APPS: [[app]] tables (name, deadline_ms, privacy, priority, rate, weight) in --config\n\
         OVERLOAD: [admission] (rate_per_s, burst, queue_ceiling, deadline_shed) in --config"
    );
}

type Flags = HashMap<String, String>;

fn parse_flags(args: &[String]) -> Result<Flags> {
    let mut flags = Flags::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let Some(key) = a.strip_prefix("--") else {
            bail!("expected --flag, got `{a}`");
        };
        let Some(val) = it.next() else {
            bail!("flag --{key} needs a value");
        };
        flags.insert(key.to_string(), val.clone());
    }
    Ok(flags)
}

fn load_config(flags: &Flags) -> Result<SystemConfig> {
    let mut cfg = match flags.get("config") {
        Some(path) => SystemConfig::load(std::path::Path::new(path))?,
        None => SystemConfig::default(),
    };
    if let Some(p) = flags.get("policy") {
        cfg.policy = PolicyKind::parse(p)?;
    }
    if let Some(n) = flags.get("images") {
        cfg.workload.n_images = n.parse().context("--images")?;
    }
    if let Some(i) = flags.get("interval") {
        cfg.workload.interval_ms = i.parse().context("--interval")?;
    }
    if let Some(d) = flags.get("deadline") {
        cfg.workload.deadline_ms = d.parse().context("--deadline")?;
    }
    if let Some(s) = flags.get("seed") {
        cfg.seed = s.parse().context("--seed")?;
    }
    if let Some(s) = flags.get("side") {
        cfg.workload.side_px = s.parse().context("--side")?;
    }
    Ok(cfg)
}

fn cmd_sim(flags: &Flags) -> Result<()> {
    let cfg = load_config(flags)?;
    if cfg.mode == RunMode::Live {
        return cmd_live(flags);
    }
    let report = ScenarioBuilder::new(cfg).run();
    println!("{}", summary_json(report.policy.as_str(), &report.summary));
    println!(
        "virtual time: {:.1} ms | events: {} | wall: {:.1} ms",
        report.virtual_ms,
        report.events,
        report.wall_us as f64 / 1e3
    );
    if let Some(path) = flags.get("csv") {
        write_csv(std::path::Path::new(path), &report.records)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_sweep(flags: &Flags) -> Result<()> {
    let cfg = load_config(flags)?;
    let builder = ScenarioBuilder::new(cfg);
    for report in builder.sweep_policies(&PolicyKind::PAPER) {
        println!("{}", summary_json(report.policy.as_str(), &report.summary));
    }
    Ok(())
}

fn cmd_repro(flags: &Flags) -> Result<()> {
    let exp = flags.get("exp").map(String::as_str).unwrap_or("all");
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(42);
    let all = exp == "all";
    let mut matched = all;

    if all || exp == "table2" {
        matched = true;
        println!("{}", experiments::table2().render());
    }
    if all || exp == "table3" {
        matched = true;
        let (a, b) = experiments::table3();
        println!("{}\n{}", a.render(), b.render());
    }
    if all || exp == "table4" {
        matched = true;
        let (a, b) = experiments::table4();
        println!("{}\n{}", a.render(), b.render());
    }
    if all || exp == "table5" {
        matched = true;
        let (a, b) = experiments::table5();
        println!("{}\n{}", a.render(), b.render());
    }
    if all || exp == "table6" {
        matched = true;
        let (a, b) = experiments::table6();
        println!("{}\n{}", a.render(), b.render());
    }
    if all || exp == "fig5" {
        matched = true;
        let rows = experiments::fig5(seed);
        println!(
            "{}",
            experiments::figures::render_policy_grid("Fig 5: 50 images, met-vs-constraint", &rows)
        );
    }
    if all || exp == "fig6" {
        matched = true;
        let rows = experiments::fig6(seed);
        println!(
            "{}",
            experiments::figures::render_policy_grid("Fig 6: 1000 images, met-vs-constraint", &rows)
        );
    }
    if all || exp == "fig7" {
        matched = true;
        let rows: Vec<_> = experiments::fig7().into_iter().map(|r| r.comparison).collect();
        println!(
            "{}",
            experiments::render_comparisons("Fig 7: CPU load vs container time", "load %", &rows)
        );
    }
    if all || exp == "fig8" {
        matched = true;
        let rows = experiments::fig8(seed);
        println!("{}", experiments::figures::render_fig8(&rows));
    }
    if all || exp == "fed" {
        matched = true;
        let rows = experiments::fed(seed);
        println!("{}", experiments::render_fed(&rows));
    }
    if all || exp == "churn" {
        matched = true;
        let rows = experiments::churn(seed);
        println!("{}", experiments::render_churn(&rows));
    }
    if all || exp == "churnsweep" {
        matched = true;
        let rows = experiments::churnsweep(seed);
        println!("{}", experiments::render_churnsweep(&rows));
    }
    if all || exp == "overload" {
        matched = true;
        // --images scales the strict tenant's stream (the CI smoke step
        // runs a reduced scenario); best-effort floods at 4× that count.
        let n_images: u32 =
            flags.get("images").map(|s| s.parse()).transpose().context("--images")?.unwrap_or(60);
        let rows = experiments::overload(seed, n_images);
        println!("{}", experiments::render_overload(&rows));
    }
    if all || exp == "gossip" {
        matched = true;
        // --images scales the stressed cell's stream (the CI smoke step
        // runs a reduced scenario).
        let n_images: u32 =
            flags.get("images").map(|s| s.parse()).transpose().context("--images")?.unwrap_or(200);
        let rows = experiments::gossip(seed, n_images);
        println!("{}", experiments::render_gossip(&rows));
    }
    if all || exp == "city" {
        matched = true;
        // --images scales each cell's diurnal stream; --cells caps the
        // sweep's city sizes (the CI smoke step runs a small city).
        let n_images: u32 =
            flags.get("images").map(|s| s.parse()).transpose().context("--images")?.unwrap_or(24);
        let max_cells: usize =
            flags.get("cells").map(|s| s.parse()).transpose().context("--cells")?.unwrap_or(256);
        let rows = experiments::city(seed, n_images, max_cells);
        println!("{}", experiments::render_city(&rows));
    }
    if all || exp == "slo" {
        matched = true;
        // --images scales the strict detector stream (the CI smoke step
        // runs a reduced scenario); default mirrors the other sweeps.
        let n_images: u32 =
            flags.get("images").map(|s| s.parse()).transpose().context("--images")?.unwrap_or(120);
        let rows = experiments::slo(seed, n_images);
        println!("{}", experiments::render_slo(&rows));
    }
    if !matched {
        bail!("unknown experiment `{exp}`");
    }
    Ok(())
}

fn cmd_live(flags: &Flags) -> Result<()> {
    let cfg = load_config(flags)?;
    let artifacts = flags.get("artifacts").map(String::as_str).unwrap_or("artifacts");
    let runtime = RuntimeService::spawn(artifacts)?;
    println!(
        "live cluster: policy={} devices={} variants={:?}",
        cfg.policy,
        cfg.devices.len(),
        runtime.sides()
    );
    let cluster = LiveCluster::start(&cfg, runtime)?;
    // Session setup settles (joins + first profile pushes).
    std::thread::sleep(Duration::from_millis(100));

    // Churn: the same expanded trace the simulator injects (scripted
    // [[churn]] plus seeded [churn_random] cycles), driven on the wall
    // clock via the kill/restart hooks (edge targets are sim-only).
    // The span covers the whole app registry ([[app]] streams).
    let span = cfg.span_ms();
    cluster.schedule_churn(&cfg.churn.expanded_events(cfg.seed, span, cfg.devices.len()));

    // Per-cell workload streams: each cell's camera originates its own
    // frames (the same derivation the simulator uses).
    let streams = ScenarioBuilder::camera_streams(&cfg);
    let n: usize = streams.iter().map(|(_, f)| f.len()).sum();
    // A joining cell's stream starts at its join time — wait for it too.
    let latest_start = ScenarioBuilder::latest_stream_start_ms(&streams);
    for (device_index, frames) in streams {
        cluster.stream_to(device_index, frames)?;
    }

    let timeout = Duration::from_secs_f64((latest_start + span + 60_000.0) / 1e3);
    let summary = cluster.wait(timeout);
    println!("{}", summary_json(&format!("live-{}", cfg.policy), &summary));
    // Per-app rows — the same table the sim experiment writers render.
    let names: Vec<String> = cfg.effective_apps().iter().map(|a| a.name.clone()).collect();
    print!("{}", edge_dds::metrics::render_per_app(&summary, &names));
    println!("streamed {n} frames; met {}/{}", summary.met, summary.total);
    cluster.shutdown();
    Ok(())
}
