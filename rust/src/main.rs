//! edge-dds launcher.
//!
//! Subcommands (hand-rolled parser — clap is not in the offline crate set):
//!
//! ```text
//! edge-dds sim    [--config cfg.toml] [--policy dds] [--images N]
//!                 [--interval MS] [--deadline MS] [--seed S] [--csv out.csv]
//!                 [--trace t.jsonl] [--timeline t.csv] [--window MS] [--stage-timing]
//! edge-dds sweep  [--config cfg.toml] [--images N] [--interval MS]
//!                 [--deadline MS]                  # all paper policies
//! edge-dds repro  --exp table2|table3|table4|table5|table6|fig5|fig6|fig7|fig8|
//!                       fed|churn|churnsweep|slo|overload|gossip|city|tier|all
//!                 [--jobs N]                            # parallel sweep points
//!                 [--trace t.jsonl] [--timeline t.csv]  # city: one observed run
//! edge-dds live   [--artifacts DIR] [--policy dds] [--images N]
//!                 [--interval MS] [--deadline MS] [--side PX]
//!                 [--trace t.jsonl] [--timeline t.csv] [--window MS]
//! ```
//!
//! Observability (DESIGN.md §Observability): `--trace` writes one JSONL
//! `TraceEvent` line per scheduler event
//! (deterministic under `--seed` in sim mode); `--timeline` writes a
//! windowed per-cell CSV time-series (`--window` ms per row, default
//! 1000); `--stage-timing` prints wall-clock per-stage histograms as a
//! `stage_ns` JSON line (sim only; never part of summaries or CSVs).
//! All knobs default off, and off means byte-identical output to builds
//! that predate them.
//!
//! Multi-cell federations are configured with `[[cell]]` tables plus a
//! per-device `cell = N` key and an optional `[federation]` section
//! (backhaul link + gossip period); see DESIGN.md §Federation. Both `sim`
//! and `live` drive them.
//!
//! Churn & failure injection (DESIGN.md §Churn): `[[churn]]` events
//! (`at_ms`, `kind = "fail"|"recover"|"join"`, `device = i` or
//! `cell = c`), optional seeded `[churn_random]` rates, and `[failure]`
//! detector thresholds. `repro --exp churn` compares deadline satisfaction
//! of DDS vs. the baselines under device churn, edge failure, and mid-run
//! cell join across 1/2/4 cells; `repro --exp churnsweep` plots met
//! fraction against the `[churn_random]` MTBF.
//!
//! Overload control (DESIGN.md §3): the `[admission]` section (per-app
//! token-bucket rate + queue ceiling + `deadline_shed`) and `[[app]]`
//! `weight` keys (weighted-fair DRR dispatch) drive the pipeline's
//! Admit/Dispatch/Overload stages; `repro --exp overload` sweeps arrival
//! rate past saturation comparing strict priority vs. admission+fair.
//!
//! Elastic cloud tier (DESIGN.md §4e): the `[cloud]` section puts one
//! pay-per-use cloud node behind every edge server over a WAN uplink;
//! DDS spills exhausted privacy-`open` frames up the uplink and the run
//! bills their cloud-seconds. `repro --exp tier` sweeps uplink latency ×
//! arrival rate × federation size comparing offload-to-cloud against
//! peer-federation under overload.

use std::collections::HashMap;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use edge_dds::config::{RunMode, SystemConfig};
use edge_dds::experiments;
use edge_dds::live::LiveCluster;
use edge_dds::metrics::{write_csv, writer::summary_json};
use edge_dds::runtime::RuntimeService;
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::ScenarioBuilder;

fn main() {
    edge_dds::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "sim" => cmd_sim(&flags),
        "sweep" => cmd_sweep(&flags),
        "repro" => cmd_repro(&flags),
        "live" => cmd_live(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `edge-dds help`)"),
    }
}

fn print_usage() {
    println!(
        "edge-dds — Dynamic Distributed Scheduler for Computing on the Edge\n\
         \n\
         USAGE:\n\
         \x20 edge-dds sim    [--config F] [--policy P] [--images N] [--interval MS]\n\
         \x20                 [--deadline MS] [--seed S] [--csv OUT]\n\
         \x20                 [--trace OUT.jsonl] [--timeline OUT.csv] [--window MS] [--stage-timing]\n\
         \x20 edge-dds sweep  [--config F] [--images N] [--interval MS] [--deadline MS]\n\
         \x20 edge-dds repro  --exp table2..table6|fig5..fig8|fed|churn|churnsweep|slo|overload|gossip|city|tier|all\n\
         \x20                 [--images N] [--cells N]   # city/gossip/overload/slo/tier scale knobs\n\
         \x20                 [--jobs N]                 # sweep points in parallel (default: cores; 1 = classic)\n\
         \x20                 [--trace OUT.jsonl] [--timeline OUT.csv]  # city: adds one observed run\n\
         \x20 edge-dds live   [--artifacts DIR] [--policy P] [--images N]\n\
         \x20                 [--interval MS] [--deadline MS] [--side PX]\n\
         \x20                 [--trace OUT.jsonl] [--timeline OUT.csv] [--window MS]\n\
         \n\
         OBSERVABILITY: --trace JSONL events (deterministic under --seed in sim),\n\
         \x20           --timeline windowed per-cell CSV, --stage-timing wall-clock\n\
         \x20           stage histograms; all off by default (byte-identical output)\n\
         \n\
         POLICIES: aor aoe eods dds dds-no-avail dds-energy round-robin random\n\
         FEDERATION: [[cell]] tables + device `cell = N` + [federation] in --config\n\
         \x20           (topology = mesh|line|ring|tree|hier[:N], max_forward_hops = N)\n\
         CHURN: [[churn]] events + [churn_random] + [failure] thresholds in --config\n\
         APPS: [[app]] tables (name, deadline_ms, privacy, priority, rate, weight) in --config\n\
         OVERLOAD: [admission] (rate_per_s, burst, queue_ceiling, deadline_shed) in --config"
    );
}

type Flags = HashMap<String, String>;

fn parse_flags(args: &[String]) -> Result<Flags> {
    let mut flags = Flags::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        let Some(key) = a.strip_prefix("--") else {
            bail!("expected --flag, got `{a}`");
        };
        // A flag is boolean (`--stage-timing`) exactly when the next
        // token is another flag or the end of the line; it parses as
        // "true". Everything else keeps the strict `--key value` shape.
        let val = match it.peek() {
            Some(v) if !v.starts_with("--") => it.next().expect("peeked").clone(),
            _ => "true".to_string(),
        };
        flags.insert(key.to_string(), val);
    }
    Ok(flags)
}

/// The observability knobs shared by `sim`, `repro --exp city` and `live`
/// (DESIGN.md §Observability): trace/timeline output paths, the timeline
/// sampling window, and the stage-timing switch.
struct ObsFlags {
    trace_path: Option<String>,
    timeline_path: Option<String>,
    window_ms: f64,
    stage_timing: bool,
}

impl ObsFlags {
    fn parse(flags: &Flags) -> Result<Self> {
        Ok(Self {
            trace_path: flags.get("trace").cloned(),
            timeline_path: flags.get("timeline").cloned(),
            window_ms: flags
                .get("window")
                .map(|s| s.parse())
                .transpose()
                .context("--window")?
                .unwrap_or(1_000.0),
            stage_timing: flags.contains_key("stage-timing"),
        })
    }

    /// Open the `--trace` sink, if any.
    fn open_trace(&self) -> Result<Option<edge_dds::metrics::trace::SharedTrace>> {
        Ok(match &self.trace_path {
            Some(p) => Some(
                edge_dds::metrics::trace::JsonlTrace::to_file(std::path::Path::new(p))
                    .with_context(|| format!("--trace {p}"))?,
            ),
            None => None,
        })
    }

    /// Flush the trace and write the timeline CSV after a run.
    fn finish(
        &self,
        trace: Option<edge_dds::metrics::trace::SharedTrace>,
        timeline: Option<&edge_dds::metrics::Timeline>,
    ) -> Result<()> {
        if let (Some(sink), Some(path)) = (trace, &self.trace_path) {
            sink.lock().unwrap().flush();
            println!("wrote {path}");
        }
        if let Some(path) = &self.timeline_path {
            let tl = timeline.context("timeline was enabled but the run produced none")?;
            tl.write(std::path::Path::new(path)).with_context(|| format!("--timeline {path}"))?;
            println!("wrote {path}");
        }
        Ok(())
    }
}

fn load_config(flags: &Flags) -> Result<SystemConfig> {
    let mut cfg = match flags.get("config") {
        Some(path) => SystemConfig::load(std::path::Path::new(path))?,
        None => SystemConfig::default(),
    };
    if let Some(p) = flags.get("policy") {
        cfg.policy = PolicyKind::parse(p)?;
    }
    if let Some(n) = flags.get("images") {
        cfg.workload.n_images = n.parse().context("--images")?;
    }
    if let Some(i) = flags.get("interval") {
        cfg.workload.interval_ms = i.parse().context("--interval")?;
    }
    if let Some(d) = flags.get("deadline") {
        cfg.workload.deadline_ms = d.parse().context("--deadline")?;
    }
    if let Some(s) = flags.get("seed") {
        cfg.seed = s.parse().context("--seed")?;
    }
    if let Some(s) = flags.get("side") {
        cfg.workload.side_px = s.parse().context("--side")?;
    }
    Ok(cfg)
}

fn cmd_sim(flags: &Flags) -> Result<()> {
    let cfg = load_config(flags)?;
    if cfg.mode == RunMode::Live {
        return cmd_live(flags);
    }
    let obs = ObsFlags::parse(flags)?;
    let trace = obs.open_trace()?;
    let mut builder = ScenarioBuilder::new(cfg);
    if let Some(t) = &trace {
        builder = builder.trace(t.clone());
    }
    if obs.timeline_path.is_some() {
        builder = builder.timeline(obs.window_ms);
    }
    if obs.stage_timing {
        builder = builder.stage_timing(true);
    }
    let report = builder.run();
    println!("{}", summary_json(report.policy.as_str(), &report.summary));
    println!(
        "virtual time: {:.1} ms | events: {} | wall: {:.1} ms",
        report.virtual_ms,
        report.events,
        report.wall_us as f64 / 1e3
    );
    if let Some(js) = &report.stage_ns {
        // Wall-clock stage histograms: a side channel by construction —
        // never part of the summary JSON replay compares.
        println!("{{\"stage_ns\":{js}}}");
    }
    if let Some(path) = flags.get("csv") {
        write_csv(std::path::Path::new(path), &report.records)?;
        println!("wrote {path}");
    }
    obs.finish(trace, report.timeline.as_ref())?;
    Ok(())
}

fn cmd_sweep(flags: &Flags) -> Result<()> {
    let cfg = load_config(flags)?;
    let builder = ScenarioBuilder::new(cfg);
    for report in builder.sweep_policies(&PolicyKind::PAPER) {
        println!("{}", summary_json(report.policy.as_str(), &report.summary));
    }
    Ok(())
}

fn cmd_repro(flags: &Flags) -> Result<()> {
    let exp = flags.get("exp").map(String::as_str).unwrap_or("all");
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(42);
    // Sweep-point parallelism (DESIGN.md §Engine internals): each point is
    // an independent seeded run, rows reassemble in enumeration order, so
    // every N renders byte-identically and `--jobs 1` is the classic loop.
    let jobs: usize = flags
        .get("jobs")
        .map(|s| s.parse())
        .transpose()
        .context("--jobs")?
        .unwrap_or_else(experiments::default_jobs)
        .max(1);
    let all = exp == "all";
    let mut matched = all;

    if all || exp == "table2" {
        matched = true;
        println!("{}", experiments::table2().render());
    }
    if all || exp == "table3" {
        matched = true;
        let (a, b) = experiments::table3();
        println!("{}\n{}", a.render(), b.render());
    }
    if all || exp == "table4" {
        matched = true;
        let (a, b) = experiments::table4();
        println!("{}\n{}", a.render(), b.render());
    }
    if all || exp == "table5" {
        matched = true;
        let (a, b) = experiments::table5();
        println!("{}\n{}", a.render(), b.render());
    }
    if all || exp == "table6" {
        matched = true;
        let (a, b) = experiments::table6();
        println!("{}\n{}", a.render(), b.render());
    }
    if all || exp == "fig5" {
        matched = true;
        let rows = experiments::fig5(seed);
        println!(
            "{}",
            experiments::figures::render_policy_grid("Fig 5: 50 images, met-vs-constraint", &rows)
        );
    }
    if all || exp == "fig6" {
        matched = true;
        let rows = experiments::fig6(seed);
        println!(
            "{}",
            experiments::figures::render_policy_grid("Fig 6: 1000 images, met-vs-constraint", &rows)
        );
    }
    if all || exp == "fig7" {
        matched = true;
        let rows: Vec<_> = experiments::fig7().into_iter().map(|r| r.comparison).collect();
        println!(
            "{}",
            experiments::render_comparisons("Fig 7: CPU load vs container time", "load %", &rows)
        );
    }
    if all || exp == "fig8" {
        matched = true;
        let rows = experiments::fig8(seed);
        println!("{}", experiments::figures::render_fig8(&rows));
    }
    if all || exp == "fed" {
        matched = true;
        let rows = experiments::fed_jobs(seed, jobs);
        println!("{}", experiments::render_fed(&rows));
    }
    if all || exp == "churn" {
        matched = true;
        let rows = experiments::churn_jobs(seed, jobs);
        println!("{}", experiments::render_churn(&rows));
    }
    if all || exp == "churnsweep" {
        matched = true;
        let rows = experiments::churnsweep_jobs(seed, jobs);
        println!("{}", experiments::render_churnsweep(&rows));
    }
    if all || exp == "overload" {
        matched = true;
        // --images scales the strict tenant's stream (the CI smoke step
        // runs a reduced scenario); best-effort floods at 4× that count.
        let n_images: u32 =
            flags.get("images").map(|s| s.parse()).transpose().context("--images")?.unwrap_or(60);
        let rows = experiments::overload_jobs(seed, n_images, jobs);
        println!("{}", experiments::render_overload(&rows));
    }
    if all || exp == "gossip" {
        matched = true;
        // --images scales the stressed cell's stream (the CI smoke step
        // runs a reduced scenario).
        let n_images: u32 =
            flags.get("images").map(|s| s.parse()).transpose().context("--images")?.unwrap_or(200);
        let rows = experiments::gossip_jobs(seed, n_images, jobs);
        println!("{}", experiments::render_gossip(&rows));
    }
    if all || exp == "city" {
        matched = true;
        // --images scales each cell's diurnal stream; --cells caps the
        // sweep's city sizes (the CI smoke step runs a small city).
        let n_images: u32 =
            flags.get("images").map(|s| s.parse()).transpose().context("--images")?.unwrap_or(24);
        let max_cells: usize =
            flags.get("cells").map(|s| s.parse()).transpose().context("--cells")?.unwrap_or(256);
        let rows = experiments::city_jobs(seed, n_images, max_cells, jobs);
        println!("{}", experiments::render_city(&rows));
        // Observability knobs add one dedicated *observed* run (the hier
        // shape at the sweep cap) — the sweep above stays knob-free.
        let obs = ObsFlags::parse(flags)?;
        if obs.trace_path.is_some() || obs.timeline_path.is_some() {
            let trace = obs.open_trace()?;
            let window = obs.timeline_path.is_some().then_some(obs.window_ms);
            let report =
                experiments::city_observed(seed, n_images, max_cells, trace.clone(), window);
            println!(
                "Observed city run (hier, {} cells): met {}/{}",
                max_cells.clamp(2, 256),
                report.summary.met,
                report.summary.total
            );
            obs.finish(trace, report.timeline.as_ref())?;
        }
    }
    if all || exp == "slo" {
        matched = true;
        // --images scales the strict detector stream (the CI smoke step
        // runs a reduced scenario); default mirrors the other sweeps.
        let n_images: u32 =
            flags.get("images").map(|s| s.parse()).transpose().context("--images")?.unwrap_or(120);
        let rows = experiments::slo_jobs(seed, n_images, jobs);
        println!("{}", experiments::render_slo(&rows));
    }
    if all || exp == "tier" {
        matched = true;
        // --images scales each tenant's stream (the CI smoke step runs a
        // reduced scenario); the sweep saturates cell 0 at the top
        // multiplier regardless of the count.
        let n_images: u32 =
            flags.get("images").map(|s| s.parse()).transpose().context("--images")?.unwrap_or(40);
        let rows = experiments::tier_jobs(seed, n_images, jobs);
        println!("{}", experiments::render_tier(&rows));
    }
    if !matched {
        bail!("unknown experiment `{exp}`");
    }
    Ok(())
}

fn cmd_live(flags: &Flags) -> Result<()> {
    let cfg = load_config(flags)?;
    let artifacts = flags.get("artifacts").map(String::as_str).unwrap_or("artifacts");
    let runtime = RuntimeService::spawn(artifacts)?;
    println!(
        "live cluster: policy={} devices={} variants={:?}",
        cfg.policy,
        cfg.devices.len(),
        runtime.sides()
    );
    let obs = ObsFlags::parse(flags)?;
    let trace = obs.open_trace()?;
    let live_obs = edge_dds::live::LiveObservability {
        trace: trace.clone(),
        timeline_window_ms: obs.timeline_path.is_some().then_some(obs.window_ms),
    };
    let cluster = LiveCluster::start_observed(&cfg, runtime, live_obs)?;
    for (edge, addr) in cluster.introspect_addrs() {
        println!("introspection: {edge} http://{addr}/metrics");
    }
    // Session setup settles (joins + first profile pushes).
    std::thread::sleep(Duration::from_millis(100));

    // Churn: the same expanded trace the simulator injects (scripted
    // [[churn]] plus seeded [churn_random] cycles), driven on the wall
    // clock via the kill/restart hooks (edge targets are sim-only).
    // The span covers the whole app registry ([[app]] streams).
    let span = cfg.span_ms();
    cluster.schedule_churn(&cfg.churn.expanded_events(cfg.seed, span, cfg.devices.len()));

    // Per-cell workload streams: each cell's camera originates its own
    // frames (the same derivation the simulator uses).
    let streams = ScenarioBuilder::camera_streams(&cfg);
    let n: usize = streams.iter().map(|(_, f)| f.len()).sum();
    // A joining cell's stream starts at its join time — wait for it too.
    let latest_start = ScenarioBuilder::latest_stream_start_ms(&streams);
    for (device_index, frames) in streams {
        cluster.stream_to(device_index, frames)?;
    }

    let timeout = Duration::from_secs_f64((latest_start + span + 60_000.0) / 1e3);
    let summary = cluster.wait(timeout);
    println!("{}", summary_json(&format!("live-{}", cfg.policy), &summary));
    // Per-app rows — the same table the sim experiment writers render.
    let names: Vec<String> = cfg.effective_apps().iter().map(|a| a.name.clone()).collect();
    print!("{}", edge_dds::metrics::render_per_app(&summary, &names));
    println!("streamed {n} frames; met {}/{}", summary.met, summary.total);
    let timeline = cluster.take_timeline();
    cluster.shutdown();
    obs.finish(trace, timeline.as_ref())?;
    Ok(())
}
