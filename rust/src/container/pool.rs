//! The container pool state machine (virtual-time) — the pipeline's
//! **Dispatch** stage (DESIGN.md §3).

use std::collections::VecDeque;

use crate::core::{AppId, ImageMeta, TaskId};
use crate::profile::ClassProfile;

/// One container's state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ContainerState {
    /// Warm and idle — in the paper's available-port queue `q`.
    Idle,
    /// Processing a task until `done_at_ms`.
    Busy { task: TaskId, done_at_ms: f64 },
    /// Cold-starting; becomes Idle at `ready_at_ms`.
    ColdStarting { ready_at_ms: f64 },
}

/// A dispatch decision: which container runs the task and until when.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Assignment {
    /// Index of the assigned container.
    pub container: usize,
    /// The task being executed.
    pub task: TaskId,
    /// Execution start (ms on the run clock).
    pub start_ms: f64,
    /// Predicted completion instant (ms on the run clock).
    pub done_at_ms: f64,
    /// Predicted in-container processing time (ms).
    pub process_ms: f64,
}

/// Aggregate pool counters (feeds UP profile pushes and metrics).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PoolStats {
    /// Images handed to a container over the pool’s lifetime.
    pub dispatched: u64,
    /// High-water mark of the overflow queue.
    pub queued_peak: usize,
    /// Containers started cold (live mode provisioning).
    pub cold_starts: u64,
}

/// Overflow-queue ordering (DESIGN.md §Constraints & QoS): per-app
/// priority first (higher dispatches first), then EDF on the absolute
/// deadline, then TaskId — a total, deterministic order. A single-app
/// uniform stream has equal priorities and deadlines ascending with
/// arrival, so this degenerates to the paper's FIFO `q_image` exactly.
fn queue_order(a: &ImageMeta, b: &ImageMeta) -> std::cmp::Ordering {
    b.constraint
        .priority
        .cmp(&a.constraint.priority)
        .then_with(|| a.abs_deadline_ms().total_cmp(&b.abs_deadline_ms()))
        .then_with(|| a.task.cmp(&b.task))
}

/// How the overflow queue orders dispatch — the pipeline's Dispatch
/// stage policy (DESIGN.md §3).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QueueDiscipline {
    /// Strict (priority desc, EDF, TaskId) — the default, byte-identical
    /// to the pre-pipeline pool.
    PriorityEdf,
    /// Deficit round robin over per-app FIFO/EDF queues: app `i` gets a
    /// long-run dispatch share proportional to `weights[i]` under
    /// saturation instead of strict priority. Enabled by `[[app]] weight`
    /// keys in the config.
    WeightedFair { weights: Vec<u32> },
    /// Backlog-stealing over per-app EDF queues: each freed (idle warm)
    /// container "steals" the EDF-front frame of the *deepest* sibling
    /// app queue (ties toward the lowest app id). Under skewed overload
    /// this drains the most backlogged tenant first — a latency-variance
    /// reducer rather than a share guarantee. Off by default; enabled by
    /// `[dispatch] work_stealing = true`.
    WorkStealing,
}

/// DRR state for [`QueueDiscipline::WeightedFair`]: per-app queues (EDF
/// within an app), per-app credit counters, and a rotating cursor. Each
/// visit to a non-empty app refills its credit to `weight` and serves up
/// to that many consecutive frames before moving on — weights 2:1 yield
/// a 2:1 dispatch share under saturation. An app whose queue drains loses
/// its residual credit (the classic DRR anti-hoarding rule).
#[derive(Debug, Clone)]
struct DrrQueues {
    weights: Vec<u32>,
    queues: Vec<VecDeque<ImageMeta>>,
    credit: Vec<u32>,
    cursor: usize,
    /// [`QueueDiscipline::WorkStealing`]: ignore weights/credit/cursor and
    /// pop the EDF-front of the deepest queue instead of rotating.
    steal: bool,
}

impl DrrQueues {
    fn new(weights: Vec<u32>) -> Self {
        let n = weights.len().max(1);
        let weights: Vec<u32> =
            (0..n).map(|i| weights.get(i).copied().unwrap_or(1).max(1)).collect();
        Self {
            queues: vec![VecDeque::new(); n],
            credit: vec![0; n],
            weights,
            cursor: 0,
            steal: false,
        }
    }

    /// Per-app queues in stealing mode ([`QueueDiscipline::WorkStealing`]).
    fn new_steal() -> Self {
        Self { steal: true, ..Self::new(Vec::new()) }
    }

    /// Grow to cover an app id beyond the registry (robustness against
    /// frames from newer configs); late apps weigh 1.
    fn ensure_app(&mut self, app: usize) {
        while self.queues.len() <= app {
            self.queues.push(VecDeque::new());
            self.credit.push(0);
            self.weights.push(1);
        }
    }

    fn enqueue(&mut self, img: ImageMeta) {
        let app = img.constraint.app.0 as usize;
        self.ensure_app(app);
        let q = &mut self.queues[app];
        // EDF within the app (priority is constant inside one app); ties
        // by TaskId — total and deterministic, like the strict queue.
        let at = q
            .binary_search_by(|e| {
                e.abs_deadline_ms()
                    .total_cmp(&img.abs_deadline_ms())
                    .then_with(|| e.task.cmp(&img.task))
            })
            .unwrap_or_else(|i| i);
        q.insert(at, img);
    }

    fn pop_next(&mut self) -> Option<ImageMeta> {
        if self.steal {
            return self.steal_next();
        }
        let n = self.queues.len();
        let mut visited = 0;
        while visited < n {
            let i = self.cursor % n;
            if self.queues[i].is_empty() {
                self.credit[i] = 0; // anti-hoarding: drained apps restart
                self.cursor = (i + 1) % n;
                visited += 1;
                continue;
            }
            if self.credit[i] == 0 {
                self.credit[i] = self.weights[i];
            }
            let img = self.queues[i].pop_front();
            self.credit[i] -= 1;
            if self.queues[i].is_empty() {
                self.credit[i] = 0; // anti-hoarding on drain
                self.cursor = (i + 1) % n;
            } else if self.credit[i] == 0 {
                self.cursor = (i + 1) % n; // quantum spent — next app
            }
            return img;
        }
        None
    }

    /// Stealing pop: the EDF-front of the deepest backlog, ties toward
    /// the lowest app id — total and deterministic like the other
    /// disciplines (queue depths and EDF order are replay state).
    fn steal_next(&mut self) -> Option<ImageMeta> {
        let mut best: Option<usize> = None;
        for (i, q) in self.queues.iter().enumerate() {
            if !q.is_empty() && best.map_or(true, |b| q.len() > self.queues[b].len()) {
                best = Some(i);
            }
        }
        self.queues[best?].pop_front()
    }

    fn len(&self) -> usize {
        self.queues.iter().map(VecDeque::len).sum()
    }

    fn len_for(&self, app: AppId) -> u32 {
        self.queues.get(app.0 as usize).map_or(0, |q| q.len() as u32)
    }

    fn clear(&mut self) {
        for q in &mut self.queues {
            q.clear();
        }
        for c in &mut self.credit {
            *c = 0;
        }
        self.cursor = 0;
    }
}

/// Warm-container pool with a priority/EDF overflow queue (the paper's
/// `q_image`, generalized for the multi-app registry), optionally under
/// weighted-fair DRR sharing ([`QueueDiscipline`]).
#[derive(Debug, Clone)]
pub struct ContainerPool {
    profile: ClassProfile,
    containers: Vec<ContainerState>,
    /// Images waiting for a container, kept sorted by [`queue_order`]
    /// (strict discipline; unused — and empty — under weighted-fair).
    queue: VecDeque<ImageMeta>,
    /// Weighted-fair DRR queues; `None` = strict (priority, EDF, task).
    fair: Option<DrrQueues>,
    /// Background (non-container) CPU load in [0, 100].
    bg_load_pct: f64,
    stats: PoolStats,
}

impl ContainerPool {
    /// A pool with `warm` pre-warmed containers (the paper pre-warms: cold
    /// starts take 52+ s, "not practical ... upon receiving a request").
    pub fn new(profile: ClassProfile, warm: u32) -> Self {
        Self {
            profile,
            containers: vec![ContainerState::Idle; warm as usize],
            queue: VecDeque::new(),
            fair: None,
            bg_load_pct: 0.0,
            stats: PoolStats::default(),
        }
    }

    /// Select the Dispatch-stage discipline (builder style). The default
    /// [`QueueDiscipline::PriorityEdf`] is a structural no-op — the pool
    /// behaves byte-identically to one built without this call.
    pub fn with_discipline(mut self, discipline: QueueDiscipline) -> Self {
        self.fair = match discipline {
            QueueDiscipline::PriorityEdf => None,
            QueueDiscipline::WeightedFair { weights } => Some(DrrQueues::new(weights)),
            QueueDiscipline::WorkStealing => Some(DrrQueues::new_steal()),
        };
        self
    }

    /// The hardware profile this pool models.
    pub fn profile(&self) -> &ClassProfile {
        &self.profile
    }

    /// Set the background (non-container) CPU load in [0, 100].
    pub fn set_bg_load(&mut self, pct: f64) {
        self.bg_load_pct = pct.clamp(0.0, 100.0);
    }

    /// Current background CPU load.
    pub fn bg_load(&self) -> f64 {
        self.bg_load_pct
    }

    /// Warm containers (busy + idle).
    pub fn warm_count(&self) -> u32 {
        self.containers
            .iter()
            .filter(|c| !matches!(c, ContainerState::ColdStarting { .. }))
            .count() as u32
    }

    /// Containers currently executing a task.
    pub fn busy_count(&self) -> u32 {
        self.containers
            .iter()
            .filter(|c| matches!(c, ContainerState::Busy { .. }))
            .count() as u32
    }

    /// Idle warm containers.
    pub fn idle_count(&self) -> u32 {
        self.containers
            .iter()
            .filter(|c| matches!(c, ContainerState::Idle))
            .count() as u32
    }

    /// Images in the overflow queue (not yet in a container).
    pub fn queued_count(&self) -> u32 {
        (self.queue.len() + self.fair.as_ref().map_or(0, DrrQueues::len)) as u32
    }

    /// Frames of `app` currently in the overflow queue (the Admit stage's
    /// per-app ceiling reads this). O(1) under weighted-fair; a scan under
    /// the strict discipline — admission is the only caller, and only when
    /// `[admission]` is configured.
    pub fn queued_for_app(&self, app: AppId) -> u32 {
        match &self.fair {
            Some(d) => d.len_for(app),
            None => self.queue.iter().filter(|i| i.constraint.app == app).count() as u32,
        }
    }

    /// Coarse predicted completion of `img` if submitted now — the
    /// Overload stage's shed test (DESIGN.md §3). With an idle container
    /// the frame starts immediately; otherwise it waits for the current
    /// batch plus `queued/warm` drain waves, each roughly one
    /// full-contention process time. Deliberately a rough lower-bound
    /// model: shedding only fires when even this optimistic estimate is
    /// already past the deadline.
    pub fn predicted_completion_ms(&self, img: &ImageMeta, now_ms: f64) -> f64 {
        if self.idle_count() > 0 {
            return now_ms + self.model_process_ms(img.size_kb, self.busy_count() + 1);
        }
        let warm = self.warm_count().max(1);
        let waves = 1 + self.queued_count() / warm;
        now_ms + self.model_process_ms(img.size_kb, warm) * (waves as f64 + 1.0)
    }

    /// Lifetime pool statistics.
    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// State of one container slot.
    pub fn state(&self, idx: usize) -> ContainerState {
        self.containers[idx]
    }

    /// Submit a task at `now_ms`: dispatch to an idle container if any,
    /// else insert into `q_image` at its (priority, deadline, task) rank
    /// and return `None`.
    pub fn submit(&mut self, img: ImageMeta, now_ms: f64) -> Option<Assignment> {
        if let Some(idx) = self.containers.iter().position(|c| matches!(c, ContainerState::Idle)) {
            Some(self.dispatch(idx, img, now_ms))
        } else {
            match &mut self.fair {
                Some(d) => d.enqueue(img),
                None => {
                    // TaskIds are unique, so the rank is total and the
                    // search never reports an exact match.
                    let at = self
                        .queue
                        .binary_search_by(|q| queue_order(q, &img))
                        .unwrap_or_else(|i| i);
                    self.queue.insert(at, img);
                }
            }
            self.stats.queued_peak = self.stats.queued_peak.max(self.queued_count() as usize);
            None
        }
    }

    /// Mark container `idx` finished `task` at `now_ms`; if `q_image` is
    /// nonempty the container immediately continues with the next image
    /// (the paper's feedback thread), returning the follow-on assignment.
    ///
    /// A completion may race a churn [`reset`](Self::reset) in live mode
    /// (the worker finished after the node was declared failed): the
    /// container is either no longer `Busy`, or — if the node already
    /// recovered and re-dispatched — busy with a *different* task. Both
    /// are no-ops: only the task the container is actually running may
    /// free it.
    pub fn complete(&mut self, idx: usize, task: TaskId, now_ms: f64) -> Option<Assignment> {
        if !matches!(self.containers[idx], ContainerState::Busy { task: t, .. } if t == task) {
            return None;
        }
        self.containers[idx] = ContainerState::Idle;
        let next = self.dequeue()?;
        Some(self.dispatch(idx, next, now_ms))
    }

    /// Next frame per the Dispatch discipline: strict head, or the DRR
    /// rotation under weighted-fair.
    fn dequeue(&mut self) -> Option<ImageMeta> {
        match &mut self.fair {
            Some(d) => d.pop_next(),
            None => self.queue.pop_front(),
        }
    }

    /// Churn: the node failed (or restarted). All in-container work and the
    /// overflow queue are lost; every warm container comes back idle (a
    /// restart reuses the pre-warmed images — cold-start cost is paid at
    /// provisioning time, not at crash recovery). Background load and
    /// lifetime stats survive.
    pub fn reset(&mut self) {
        for c in &mut self.containers {
            *c = ContainerState::Idle;
        }
        self.queue.clear();
        if let Some(d) = &mut self.fair {
            d.clear();
        }
    }

    /// Begin a cold start at `now_ms`; the new container becomes idle at
    /// the returned time (Table III/IV calibration: cost grows with the
    /// number of containers already present).
    pub fn start_cold(&mut self, now_ms: f64) -> f64 {
        let n_existing = self.containers.len().max(1) as u32;
        let ready_at = now_ms + self.profile.cold_start_ms(n_existing);
        self.containers.push(ContainerState::ColdStarting { ready_at_ms: ready_at });
        self.stats.cold_starts += 1;
        ready_at
    }

    /// Transition any finished cold starts to Idle (call when time passes),
    /// then drain the queue into newly idle containers. Returns the
    /// assignments made.
    pub fn tick(&mut self, now_ms: f64) -> Vec<Assignment> {
        for c in &mut self.containers {
            if let ContainerState::ColdStarting { ready_at_ms } = *c {
                if ready_at_ms <= now_ms {
                    *c = ContainerState::Idle;
                }
            }
        }
        let mut out = Vec::new();
        while self.queued_count() > 0 {
            let Some(idx) =
                self.containers.iter().position(|c| matches!(c, ContainerState::Idle))
            else {
                break;
            };
            let img = self.dequeue().unwrap();
            out.push(self.dispatch(idx, img, now_ms));
        }
        out
    }

    /// The model's processing time for an image dispatched right now
    /// (used by the pool itself and by live mode for comparison metrics).
    pub fn model_process_ms(&self, size_kb: f64, concurrency: u32) -> f64 {
        self.profile.process_ms(size_kb, concurrency, self.bg_load_pct)
    }

    fn dispatch(&mut self, idx: usize, img: ImageMeta, now_ms: f64) -> Assignment {
        // Contention counts this task itself: dispatching onto a pool with
        // b busy containers runs at concurrency b+1 (Table V semantics —
        // "average processing time of one image in a container" with n
        // containers all running).
        let concurrency = self.busy_count() + 1;
        let process_ms = self.model_process_ms(img.size_kb, concurrency);
        let done = now_ms + process_ms;
        self.containers[idx] = ContainerState::Busy { task: img.task, done_at_ms: done };
        self.stats.dispatched += 1;
        Assignment {
            container: idx,
            task: img.task,
            start_ms: now_ms,
            done_at_ms: done,
            process_ms,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Constraint, NodeClass, NodeId};
    use crate::profile::profile_for;

    fn img(task: u64, size_kb: f64) -> ImageMeta {
        ImageMeta {
            task: TaskId(task),
            origin: NodeId(1),
            size_kb,
            side_px: 64,
            created_ms: 0.0,
            constraint: Constraint::deadline(5000.0),
            seq: task,
        }
    }

    fn edge_pool(warm: u32) -> ContainerPool {
        ContainerPool::new(profile_for(NodeClass::EdgeServer), warm)
    }

    #[test]
    fn single_dispatch_is_table2_time() {
        let mut p = edge_pool(1);
        let a = p.submit(img(1, 29.0), 0.0).unwrap();
        assert!((a.process_ms - 223.0).abs() < 1e-9);
        assert_eq!(p.busy_count(), 1);
        assert_eq!(p.idle_count(), 0);
    }

    #[test]
    fn overflow_queues_fifo() {
        let mut p = edge_pool(1);
        assert!(p.submit(img(1, 29.0), 0.0).is_some());
        assert!(p.submit(img(2, 29.0), 1.0).is_none());
        assert!(p.submit(img(3, 29.0), 2.0).is_none());
        assert_eq!(p.queued_count(), 2);
        // Completion pulls task 2 first (FIFO).
        let next = p.complete(0, TaskId(1), 223.0).unwrap();
        assert_eq!(next.task, TaskId(2));
        assert_eq!(p.queued_count(), 1);
    }

    #[test]
    fn contention_scales_with_busy() {
        let mut p = edge_pool(4);
        let a1 = p.submit(img(1, 29.0), 0.0).unwrap();
        let a2 = p.submit(img(2, 29.0), 0.0).unwrap();
        let a3 = p.submit(img(3, 29.0), 0.0).unwrap();
        let a4 = p.submit(img(4, 29.0), 0.0).unwrap();
        // Table V: 223, 273, 366, 464 for n = 1..4.
        assert!((a1.process_ms - 223.0).abs() < 1e-9);
        assert!((a2.process_ms - 273.0).abs() < 1e-9);
        assert!((a3.process_ms - 366.0).abs() < 1e-9);
        assert!((a4.process_ms - 464.0).abs() < 1e-9);
    }

    #[test]
    fn bg_load_slows_processing() {
        let mut p = edge_pool(1);
        p.set_bg_load(100.0);
        let a = p.submit(img(1, 29.0), 0.0).unwrap();
        assert!((a.process_ms - 374.0).abs() < 1e-9); // Fig. 7 @ 100 %
    }

    #[test]
    fn cold_start_times_from_table3() {
        let mut p = edge_pool(1);
        let ready = p.start_cold(0.0);
        assert!((ready - 52_554.0).abs() < 1e-9);
        assert_eq!(p.warm_count(), 1); // cold one not yet warm
        let mut ticked = p.tick(60_000.0);
        assert!(ticked.is_empty());
        assert_eq!(p.warm_count(), 2);
        ticked = p.tick(60_000.0);
        assert!(ticked.is_empty());
    }

    #[test]
    fn tick_drains_queue_after_cold_start() {
        let mut p = edge_pool(1);
        p.submit(img(1, 29.0), 0.0).unwrap();
        assert!(p.submit(img(2, 29.0), 0.0).is_none());
        p.start_cold(0.0);
        let assigns = p.tick(52_554.0);
        assert_eq!(assigns.len(), 1);
        assert_eq!(assigns[0].task, TaskId(2));
    }

    #[test]
    fn stats_track_activity() {
        let mut p = edge_pool(1);
        p.submit(img(1, 29.0), 0.0);
        p.submit(img(2, 29.0), 0.0);
        p.submit(img(3, 29.0), 0.0);
        let s = p.stats();
        assert_eq!(s.dispatched, 1);
        assert_eq!(s.queued_peak, 2);
    }

    #[test]
    fn rpi_pool_uses_rpi_profile() {
        let mut p = ContainerPool::new(profile_for(NodeClass::RaspberryPi), 1);
        let a = p.submit(img(1, 29.0), 0.0).unwrap();
        assert!((a.process_ms - 597.0).abs() < 1e-9); // Table VI n=1
    }

    #[test]
    fn queue_orders_by_priority_then_deadline_then_task() {
        use crate::core::{AppId, Constraint, PrivacyClass};
        let mut p = edge_pool(1);
        p.submit(img(0, 29.0), 0.0).unwrap(); // occupies the container
        // Queue: low-priority early-deadline, high-priority late-deadline,
        // and two equal-priority frames ordered by absolute deadline.
        let mut lo_early = img(1, 29.0);
        lo_early.constraint = Constraint::for_app(AppId(1), 1_000.0, PrivacyClass::Open, 0);
        let mut hi_late = img(2, 29.0);
        hi_late.constraint = Constraint::for_app(AppId(2), 50_000.0, PrivacyClass::Open, 5);
        let mut mid_late = img(3, 29.0);
        mid_late.constraint = Constraint::for_app(AppId(3), 9_000.0, PrivacyClass::Open, 1);
        let mut mid_early = img(4, 29.0);
        mid_early.constraint = Constraint::for_app(AppId(3), 4_000.0, PrivacyClass::Open, 1);
        for f in [lo_early, hi_late, mid_late, mid_early] {
            assert!(p.submit(f, 1.0).is_none());
        }
        // Dispatch order: priority 5, then priority 1 by deadline
        // (4000 before 9000), then priority 0.
        let order: Vec<u64> = std::iter::from_fn(|| {
            let next = p.complete(0, p_busy_task(&p), 10.0)?;
            Some(next.task.0)
        })
        .collect();
        assert_eq!(order, vec![2, 4, 3, 1]);
    }

    /// The task currently running in container 0 (test helper).
    fn p_busy_task(p: &ContainerPool) -> TaskId {
        match p.state(0) {
            ContainerState::Busy { task, .. } => task,
            other => panic!("container 0 not busy: {other:?}"),
        }
    }

    #[test]
    fn equal_priority_equal_deadline_ties_break_by_task_id() {
        let mut p = edge_pool(1);
        p.submit(img(0, 29.0), 0.0).unwrap();
        // Same created_ms/deadline → same rank up to the TaskId tie-break;
        // insertion order scrambled on purpose.
        for t in [7u64, 3, 9, 5] {
            assert!(p.submit(img(t, 29.0), 0.0).is_none());
        }
        let mut order = Vec::new();
        let mut running = p_busy_task(&p);
        while let Some(next) = p.complete(0, running, 10.0) {
            order.push(next.task.0);
            running = next.task;
        }
        assert_eq!(order, vec![3, 5, 7, 9]);
    }

    #[test]
    fn single_app_uniform_stream_queue_is_fifo() {
        // Legacy identity: one app, arrivals in time order → deadlines
        // ascend with arrival, so the priority queue reproduces FIFO.
        let mut p = edge_pool(1);
        for t in 0..6u64 {
            let mut f = img(t, 29.0);
            f.created_ms = t as f64 * 10.0;
            p.submit(f, f.created_ms);
        }
        let mut order = Vec::new();
        let mut running = p_busy_task(&p);
        while let Some(next) = p.complete(0, running, 100.0) {
            order.push(next.task.0);
            running = next.task;
        }
        assert_eq!(order, vec![1, 2, 3, 4, 5]);
    }

    // ---- weighted-fair DRR (pipeline Dispatch stage, DESIGN.md §3) ----

    fn app_img(task: u64, app: u16, deadline: f64) -> ImageMeta {
        use crate::core::{AppId, Constraint, PrivacyClass};
        let mut f = img(task, 29.0);
        f.constraint = Constraint::for_app(AppId(app), deadline, PrivacyClass::Open, 0);
        f
    }

    fn fair_pool(weights: &[u32]) -> ContainerPool {
        ContainerPool::new(profile_for(NodeClass::EdgeServer), 1)
            .with_discipline(QueueDiscipline::WeightedFair { weights: weights.to_vec() })
    }

    #[test]
    fn drr_weights_two_to_one_yield_two_to_one_share() {
        let mut p = fair_pool(&[2, 1]);
        p.submit(img(0, 29.0), 0.0).unwrap(); // occupy the container
        // Saturation: 12 queued frames of each app, interleaved arrival.
        for t in 0..12u64 {
            assert!(p.submit(app_img(100 + t, 0, 1e6), 1.0).is_none());
            assert!(p.submit(app_img(200 + t, 1, 1e6), 1.0).is_none());
        }
        let mut order = Vec::new();
        let mut running = p_busy_task(&p);
        while let Some(next) = p.complete(0, running, 10.0) {
            order.push(next.task.0);
            running = next.task;
        }
        assert_eq!(order.len(), 24);
        // DRR 2:1 → pattern (A A B) repeating while both queues are
        // backlogged: after any 3k dispatches, app 0 got 2k and app 1
        // got k. App 0's 12 frames last exactly 6 rounds (18 dispatches);
        // the residual app-1 backlog drains afterwards.
        for k in 1..=6usize {
            let window = &order[..3 * k];
            let a = window.iter().filter(|t| **t < 200).count();
            assert_eq!(a, 2 * k, "after {} dispatches: {window:?}", 3 * k);
        }
        assert!(order[18..].iter().all(|t| *t >= 200), "tail is the app-1 backlog");
        // Within each app, EDF/TaskId order is preserved.
        let a_order: Vec<u64> = order.iter().copied().filter(|t| *t < 200).collect();
        assert!(a_order.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn drr_does_not_starve_and_drains_to_other_apps() {
        let mut p = fair_pool(&[3, 1]);
        p.submit(img(0, 29.0), 0.0).unwrap();
        // Only app 1 has traffic: it gets every dispatch slot.
        for t in 0..4u64 {
            p.submit(app_img(200 + t, 1, 1e6), 1.0);
        }
        let mut running = p_busy_task(&p);
        let mut served = 0;
        while let Some(next) = p.complete(0, running, 10.0) {
            assert!(next.task.0 >= 200);
            served += 1;
            running = next.task;
        }
        assert_eq!(served, 4);
        // App 0 traffic arriving later is not owed a hoarded backlog of
        // credit: the anti-hoarding rule reset it on drain.
        for t in 0..2u64 {
            p.submit(app_img(100 + t, 0, 1e6), 20.0);
        }
        let next = p.complete(0, running, 30.0).unwrap();
        assert_eq!(next.task.0, 100);
    }

    #[test]
    fn drr_handles_app_ids_beyond_registry() {
        let mut p = fair_pool(&[1]);
        p.submit(img(0, 29.0), 0.0).unwrap();
        // App 5 was never registered: the DRR grows to cover it (weight 1).
        p.submit(app_img(500, 5, 1e6), 1.0);
        assert_eq!(p.queued_count(), 1);
        assert_eq!(p.queued_for_app(crate::core::AppId(5)), 1);
        let next = p.complete(0, p_busy_task(&p), 10.0).unwrap();
        assert_eq!(next.task.0, 500);
    }

    #[test]
    fn fair_reset_clears_queues_and_state() {
        let mut p = fair_pool(&[2, 1]);
        p.submit(img(0, 29.0), 0.0).unwrap();
        p.submit(app_img(100, 0, 1e6), 1.0);
        p.submit(app_img(200, 1, 1e6), 1.0);
        assert_eq!(p.queued_count(), 2);
        p.reset();
        assert_eq!(p.queued_count(), 0);
        assert!(p.complete(0, TaskId(0), 10.0).is_none());
        // Accepts fresh work after the reset.
        assert!(p.submit(app_img(300, 1, 1e6), 20.0).is_some());
    }

    #[test]
    fn fair_tick_drains_via_drr() {
        let mut p = fair_pool(&[2, 1]);
        p.submit(img(0, 29.0), 0.0).unwrap();
        for t in 0..3u64 {
            p.submit(app_img(100 + t, 0, 1e6), 1.0);
            p.submit(app_img(200 + t, 1, 1e6), 1.0);
        }
        p.start_cold(1.0);
        p.start_cold(1.0);
        let assigns = p.tick(200_000.0);
        // Two cold containers came up: the first two DRR picks run.
        assert_eq!(assigns.len(), 2);
        assert_eq!(assigns[0].task.0, 100);
        assert_eq!(assigns[1].task.0, 101);
        assert_eq!(p.queued_count(), 4);
    }

    // ---- work stealing (PR-9 satellite, DESIGN.md §Engine internals) ----

    fn steal_pool() -> ContainerPool {
        ContainerPool::new(profile_for(NodeClass::EdgeServer), 1)
            .with_discipline(QueueDiscipline::WorkStealing)
    }

    #[test]
    fn stealing_drains_the_deepest_app_queue_first() {
        let mut p = steal_pool();
        p.submit(img(0, 29.0), 0.0).unwrap(); // occupy the container
        // App 0: one frame; app 1: three frames — the backlog.
        p.submit(app_img(100, 0, 1e6), 1.0);
        for t in 0..3u64 {
            p.submit(app_img(200 + t, 1, 1e6), 1.0);
        }
        let mut order = Vec::new();
        let mut running = p_busy_task(&p);
        while let Some(next) = p.complete(0, running, 10.0) {
            order.push(next.task.0);
            running = next.task;
        }
        // Deepest-first: app 1 until its depth drops to app 0's (3, 2,
        // then tie at 1-vs-1 → lowest app id), EDF order within the app.
        assert_eq!(order, vec![200, 201, 100, 202]);
    }

    #[test]
    fn stealing_tie_breaks_toward_the_lowest_app_id() {
        let mut p = steal_pool();
        p.submit(img(0, 29.0), 0.0).unwrap();
        // Equal depths: app 2 enqueued first must not win the tie.
        p.submit(app_img(300, 2, 1e6), 1.0);
        p.submit(app_img(100, 0, 1e6), 1.0);
        let next = p.complete(0, p_busy_task(&p), 10.0).unwrap();
        assert_eq!(next.task.0, 100);
    }

    #[test]
    fn stealing_pops_edf_front_within_the_stolen_queue() {
        let mut p = steal_pool();
        p.submit(img(0, 29.0), 0.0).unwrap();
        // Later-submitted frame has the earlier absolute deadline.
        p.submit(app_img(201, 1, 1e6), 1.0);
        p.submit(app_img(200, 1, 5_000.0), 1.0);
        let next = p.complete(0, p_busy_task(&p), 10.0).unwrap();
        assert_eq!(next.task.0, 200, "EDF front, not FIFO front");
    }

    #[test]
    fn strict_discipline_builder_is_identity() {
        // `with_discipline(PriorityEdf)` must leave the classic pool
        // behaviour untouched (the legacy byte-identical path).
        let mk = |strict: bool| {
            let mut p = ContainerPool::new(profile_for(NodeClass::EdgeServer), 1);
            if strict {
                p = p.with_discipline(QueueDiscipline::PriorityEdf);
            }
            p.submit(img(0, 29.0), 0.0).unwrap();
            for t in [7u64, 3, 9, 5] {
                p.submit(img(t, 29.0), 0.0);
            }
            let mut order = Vec::new();
            let mut running = p_busy_task(&p);
            while let Some(next) = p.complete(0, running, 10.0) {
                order.push(next.task.0);
                running = next.task;
            }
            order
        };
        assert_eq!(mk(true), mk(false));
    }

    #[test]
    fn queued_for_app_counts_under_both_disciplines() {
        use crate::core::AppId;
        for fair in [false, true] {
            let mut p = ContainerPool::new(profile_for(NodeClass::EdgeServer), 1);
            if fair {
                p = p.with_discipline(QueueDiscipline::WeightedFair { weights: vec![1, 1] });
            }
            p.submit(img(0, 29.0), 0.0).unwrap();
            p.submit(app_img(100, 0, 1e6), 1.0);
            p.submit(app_img(101, 0, 1e6), 1.0);
            p.submit(app_img(200, 1, 1e6), 1.0);
            assert_eq!(p.queued_for_app(AppId(0)), 2, "fair={fair}");
            assert_eq!(p.queued_for_app(AppId(1)), 1, "fair={fair}");
            assert_eq!(p.queued_for_app(AppId(9)), 0, "fair={fair}");
        }
    }

    #[test]
    fn predicted_completion_coarse_model() {
        let mut p = edge_pool(2);
        let f = img(1, 29.0);
        // Idle pool: now + single-dispatch process time (223 ms).
        assert!((p.predicted_completion_ms(&f, 100.0) - 323.0).abs() < 1e-9);
        // Saturate: 2 busy, 4 queued → waves = 1 + 4/2 = 3, concurrency-2
        // process 273 ms → 100 + 273 * 4.
        p.submit(img(10, 29.0), 100.0).unwrap();
        p.submit(img(11, 29.0), 100.0).unwrap();
        for t in 12..16u64 {
            p.submit(img(t, 29.0), 100.0);
        }
        let got = p.predicted_completion_ms(&f, 100.0);
        assert!((got - (100.0 + 273.0 * 4.0)).abs() < 1e-6, "got {got}");
    }

    #[test]
    fn complete_empty_queue_returns_none() {
        let mut p = edge_pool(1);
        p.submit(img(1, 29.0), 0.0).unwrap();
        assert!(p.complete(0, TaskId(1), 223.0).is_none());
        assert_eq!(p.idle_count(), 1);
    }

    #[test]
    fn reset_clears_work_and_queue_keeps_capacity_and_load() {
        let mut p = edge_pool(2);
        p.set_bg_load(50.0);
        p.submit(img(1, 29.0), 0.0).unwrap();
        p.submit(img(2, 29.0), 0.0).unwrap();
        assert!(p.submit(img(3, 29.0), 0.0).is_none());
        p.reset();
        assert_eq!(p.busy_count(), 0);
        assert_eq!(p.queued_count(), 0);
        assert_eq!(p.warm_count(), 2);
        assert_eq!(p.bg_load(), 50.0);
        // Restarted pool accepts work again.
        assert!(p.submit(img(4, 29.0), 10.0).is_some());
    }

    #[test]
    fn completion_racing_reset_is_a_noop() {
        let mut p = edge_pool(1);
        p.submit(img(1, 29.0), 0.0).unwrap();
        assert!(p.submit(img(2, 29.0), 0.0).is_none());
        p.reset();
        // The worker for task 1 reports after the reset: nothing dispatched,
        // nothing panics, and the (cleared) queue stays empty.
        assert!(p.complete(0, TaskId(1), 223.0).is_none());
        assert_eq!(p.busy_count(), 0);
        assert_eq!(p.queued_count(), 0);
    }

    #[test]
    fn stale_completion_for_reassigned_container_is_a_noop() {
        // Live churn race: container 0 runs task 1, the node resets, task 3
        // is re-dispatched onto container 0 — then task 1's worker finally
        // reports. The stale completion must not free task 3's container.
        let mut p = edge_pool(1);
        p.submit(img(1, 29.0), 0.0).unwrap();
        p.reset();
        p.submit(img(3, 29.0), 10.0).unwrap();
        assert!(p.complete(0, TaskId(1), 400.0).is_none());
        assert_eq!(p.busy_count(), 1, "task 3 must keep its container");
        // The genuine completion still works.
        assert!(p.complete(0, TaskId(3), 500.0).is_none());
        assert_eq!(p.busy_count(), 0);
    }
}
