//! The container pool state machine (virtual-time).

use std::collections::VecDeque;

use crate::core::{ImageMeta, TaskId};
use crate::profile::ClassProfile;

/// One container's state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ContainerState {
    /// Warm and idle — in the paper's available-port queue `q`.
    Idle,
    /// Processing a task until `done_at_ms`.
    Busy { task: TaskId, done_at_ms: f64 },
    /// Cold-starting; becomes Idle at `ready_at_ms`.
    ColdStarting { ready_at_ms: f64 },
}

/// A dispatch decision: which container runs the task and until when.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Assignment {
    pub container: usize,
    pub task: TaskId,
    pub start_ms: f64,
    pub done_at_ms: f64,
    pub process_ms: f64,
}

/// Aggregate pool counters (feeds UP profile pushes and metrics).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PoolStats {
    pub dispatched: u64,
    pub queued_peak: usize,
    pub cold_starts: u64,
}

/// Overflow-queue ordering (DESIGN.md §Constraints & QoS): per-app
/// priority first (higher dispatches first), then EDF on the absolute
/// deadline, then TaskId — a total, deterministic order. A single-app
/// uniform stream has equal priorities and deadlines ascending with
/// arrival, so this degenerates to the paper's FIFO `q_image` exactly.
fn queue_order(a: &ImageMeta, b: &ImageMeta) -> std::cmp::Ordering {
    b.constraint
        .priority
        .cmp(&a.constraint.priority)
        .then_with(|| a.abs_deadline_ms().total_cmp(&b.abs_deadline_ms()))
        .then_with(|| a.task.cmp(&b.task))
}

/// Warm-container pool with a priority/EDF overflow queue (the paper's
/// `q_image`, generalized for the multi-app registry).
#[derive(Debug, Clone)]
pub struct ContainerPool {
    profile: ClassProfile,
    containers: Vec<ContainerState>,
    /// Images waiting for a container, kept sorted by [`queue_order`].
    queue: VecDeque<ImageMeta>,
    /// Background (non-container) CPU load in [0, 100].
    bg_load_pct: f64,
    stats: PoolStats,
}

impl ContainerPool {
    /// A pool with `warm` pre-warmed containers (the paper pre-warms: cold
    /// starts take 52+ s, "not practical ... upon receiving a request").
    pub fn new(profile: ClassProfile, warm: u32) -> Self {
        Self {
            profile,
            containers: vec![ContainerState::Idle; warm as usize],
            queue: VecDeque::new(),
            bg_load_pct: 0.0,
            stats: PoolStats::default(),
        }
    }

    pub fn profile(&self) -> &ClassProfile {
        &self.profile
    }

    pub fn set_bg_load(&mut self, pct: f64) {
        self.bg_load_pct = pct.clamp(0.0, 100.0);
    }

    pub fn bg_load(&self) -> f64 {
        self.bg_load_pct
    }

    pub fn warm_count(&self) -> u32 {
        self.containers
            .iter()
            .filter(|c| !matches!(c, ContainerState::ColdStarting { .. }))
            .count() as u32
    }

    pub fn busy_count(&self) -> u32 {
        self.containers
            .iter()
            .filter(|c| matches!(c, ContainerState::Busy { .. }))
            .count() as u32
    }

    pub fn idle_count(&self) -> u32 {
        self.containers
            .iter()
            .filter(|c| matches!(c, ContainerState::Idle))
            .count() as u32
    }

    pub fn queued_count(&self) -> u32 {
        self.queue.len() as u32
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    pub fn state(&self, idx: usize) -> ContainerState {
        self.containers[idx]
    }

    /// Submit a task at `now_ms`: dispatch to an idle container if any,
    /// else insert into `q_image` at its (priority, deadline, task) rank
    /// and return `None`.
    pub fn submit(&mut self, img: ImageMeta, now_ms: f64) -> Option<Assignment> {
        if let Some(idx) = self.containers.iter().position(|c| matches!(c, ContainerState::Idle)) {
            Some(self.dispatch(idx, img, now_ms))
        } else {
            // TaskIds are unique, so the rank is total and the search
            // never reports an exact match.
            let at = self
                .queue
                .binary_search_by(|q| queue_order(q, &img))
                .unwrap_or_else(|i| i);
            self.queue.insert(at, img);
            self.stats.queued_peak = self.stats.queued_peak.max(self.queue.len());
            None
        }
    }

    /// Mark container `idx` finished `task` at `now_ms`; if `q_image` is
    /// nonempty the container immediately continues with the next image
    /// (the paper's feedback thread), returning the follow-on assignment.
    ///
    /// A completion may race a churn [`reset`](Self::reset) in live mode
    /// (the worker finished after the node was declared failed): the
    /// container is either no longer `Busy`, or — if the node already
    /// recovered and re-dispatched — busy with a *different* task. Both
    /// are no-ops: only the task the container is actually running may
    /// free it.
    pub fn complete(&mut self, idx: usize, task: TaskId, now_ms: f64) -> Option<Assignment> {
        if !matches!(self.containers[idx], ContainerState::Busy { task: t, .. } if t == task) {
            return None;
        }
        self.containers[idx] = ContainerState::Idle;
        let next = self.queue.pop_front()?;
        Some(self.dispatch(idx, next, now_ms))
    }

    /// Churn: the node failed (or restarted). All in-container work and the
    /// overflow queue are lost; every warm container comes back idle (a
    /// restart reuses the pre-warmed images — cold-start cost is paid at
    /// provisioning time, not at crash recovery). Background load and
    /// lifetime stats survive.
    pub fn reset(&mut self) {
        for c in &mut self.containers {
            *c = ContainerState::Idle;
        }
        self.queue.clear();
    }

    /// Begin a cold start at `now_ms`; the new container becomes idle at
    /// the returned time (Table III/IV calibration: cost grows with the
    /// number of containers already present).
    pub fn start_cold(&mut self, now_ms: f64) -> f64 {
        let n_existing = self.containers.len().max(1) as u32;
        let ready_at = now_ms + self.profile.cold_start_ms(n_existing);
        self.containers.push(ContainerState::ColdStarting { ready_at_ms: ready_at });
        self.stats.cold_starts += 1;
        ready_at
    }

    /// Transition any finished cold starts to Idle (call when time passes),
    /// then drain the queue into newly idle containers. Returns the
    /// assignments made.
    pub fn tick(&mut self, now_ms: f64) -> Vec<Assignment> {
        for c in &mut self.containers {
            if let ContainerState::ColdStarting { ready_at_ms } = *c {
                if ready_at_ms <= now_ms {
                    *c = ContainerState::Idle;
                }
            }
        }
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let Some(idx) =
                self.containers.iter().position(|c| matches!(c, ContainerState::Idle))
            else {
                break;
            };
            let img = self.queue.pop_front().unwrap();
            out.push(self.dispatch(idx, img, now_ms));
        }
        out
    }

    /// The model's processing time for an image dispatched right now
    /// (used by the pool itself and by live mode for comparison metrics).
    pub fn model_process_ms(&self, size_kb: f64, concurrency: u32) -> f64 {
        self.profile.process_ms(size_kb, concurrency, self.bg_load_pct)
    }

    fn dispatch(&mut self, idx: usize, img: ImageMeta, now_ms: f64) -> Assignment {
        // Contention counts this task itself: dispatching onto a pool with
        // b busy containers runs at concurrency b+1 (Table V semantics —
        // "average processing time of one image in a container" with n
        // containers all running).
        let concurrency = self.busy_count() + 1;
        let process_ms = self.model_process_ms(img.size_kb, concurrency);
        let done = now_ms + process_ms;
        self.containers[idx] = ContainerState::Busy { task: img.task, done_at_ms: done };
        self.stats.dispatched += 1;
        Assignment {
            container: idx,
            task: img.task,
            start_ms: now_ms,
            done_at_ms: done,
            process_ms,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Constraint, NodeClass, NodeId};
    use crate::profile::profile_for;

    fn img(task: u64, size_kb: f64) -> ImageMeta {
        ImageMeta {
            task: TaskId(task),
            origin: NodeId(1),
            size_kb,
            side_px: 64,
            created_ms: 0.0,
            constraint: Constraint::deadline(5000.0),
            seq: task,
        }
    }

    fn edge_pool(warm: u32) -> ContainerPool {
        ContainerPool::new(profile_for(NodeClass::EdgeServer), warm)
    }

    #[test]
    fn single_dispatch_is_table2_time() {
        let mut p = edge_pool(1);
        let a = p.submit(img(1, 29.0), 0.0).unwrap();
        assert!((a.process_ms - 223.0).abs() < 1e-9);
        assert_eq!(p.busy_count(), 1);
        assert_eq!(p.idle_count(), 0);
    }

    #[test]
    fn overflow_queues_fifo() {
        let mut p = edge_pool(1);
        assert!(p.submit(img(1, 29.0), 0.0).is_some());
        assert!(p.submit(img(2, 29.0), 1.0).is_none());
        assert!(p.submit(img(3, 29.0), 2.0).is_none());
        assert_eq!(p.queued_count(), 2);
        // Completion pulls task 2 first (FIFO).
        let next = p.complete(0, TaskId(1), 223.0).unwrap();
        assert_eq!(next.task, TaskId(2));
        assert_eq!(p.queued_count(), 1);
    }

    #[test]
    fn contention_scales_with_busy() {
        let mut p = edge_pool(4);
        let a1 = p.submit(img(1, 29.0), 0.0).unwrap();
        let a2 = p.submit(img(2, 29.0), 0.0).unwrap();
        let a3 = p.submit(img(3, 29.0), 0.0).unwrap();
        let a4 = p.submit(img(4, 29.0), 0.0).unwrap();
        // Table V: 223, 273, 366, 464 for n = 1..4.
        assert!((a1.process_ms - 223.0).abs() < 1e-9);
        assert!((a2.process_ms - 273.0).abs() < 1e-9);
        assert!((a3.process_ms - 366.0).abs() < 1e-9);
        assert!((a4.process_ms - 464.0).abs() < 1e-9);
    }

    #[test]
    fn bg_load_slows_processing() {
        let mut p = edge_pool(1);
        p.set_bg_load(100.0);
        let a = p.submit(img(1, 29.0), 0.0).unwrap();
        assert!((a.process_ms - 374.0).abs() < 1e-9); // Fig. 7 @ 100 %
    }

    #[test]
    fn cold_start_times_from_table3() {
        let mut p = edge_pool(1);
        let ready = p.start_cold(0.0);
        assert!((ready - 52_554.0).abs() < 1e-9);
        assert_eq!(p.warm_count(), 1); // cold one not yet warm
        let mut ticked = p.tick(60_000.0);
        assert!(ticked.is_empty());
        assert_eq!(p.warm_count(), 2);
        ticked = p.tick(60_000.0);
        assert!(ticked.is_empty());
    }

    #[test]
    fn tick_drains_queue_after_cold_start() {
        let mut p = edge_pool(1);
        p.submit(img(1, 29.0), 0.0).unwrap();
        assert!(p.submit(img(2, 29.0), 0.0).is_none());
        p.start_cold(0.0);
        let assigns = p.tick(52_554.0);
        assert_eq!(assigns.len(), 1);
        assert_eq!(assigns[0].task, TaskId(2));
    }

    #[test]
    fn stats_track_activity() {
        let mut p = edge_pool(1);
        p.submit(img(1, 29.0), 0.0);
        p.submit(img(2, 29.0), 0.0);
        p.submit(img(3, 29.0), 0.0);
        let s = p.stats();
        assert_eq!(s.dispatched, 1);
        assert_eq!(s.queued_peak, 2);
    }

    #[test]
    fn rpi_pool_uses_rpi_profile() {
        let mut p = ContainerPool::new(profile_for(NodeClass::RaspberryPi), 1);
        let a = p.submit(img(1, 29.0), 0.0).unwrap();
        assert!((a.process_ms - 597.0).abs() < 1e-9); // Table VI n=1
    }

    #[test]
    fn queue_orders_by_priority_then_deadline_then_task() {
        use crate::core::{AppId, Constraint, PrivacyClass};
        let mut p = edge_pool(1);
        p.submit(img(0, 29.0), 0.0).unwrap(); // occupies the container
        // Queue: low-priority early-deadline, high-priority late-deadline,
        // and two equal-priority frames ordered by absolute deadline.
        let mut lo_early = img(1, 29.0);
        lo_early.constraint = Constraint::for_app(AppId(1), 1_000.0, PrivacyClass::Open, 0);
        let mut hi_late = img(2, 29.0);
        hi_late.constraint = Constraint::for_app(AppId(2), 50_000.0, PrivacyClass::Open, 5);
        let mut mid_late = img(3, 29.0);
        mid_late.constraint = Constraint::for_app(AppId(3), 9_000.0, PrivacyClass::Open, 1);
        let mut mid_early = img(4, 29.0);
        mid_early.constraint = Constraint::for_app(AppId(3), 4_000.0, PrivacyClass::Open, 1);
        for f in [lo_early, hi_late, mid_late, mid_early] {
            assert!(p.submit(f, 1.0).is_none());
        }
        // Dispatch order: priority 5, then priority 1 by deadline
        // (4000 before 9000), then priority 0.
        let order: Vec<u64> = std::iter::from_fn(|| {
            let next = p.complete(0, p_busy_task(&p), 10.0)?;
            Some(next.task.0)
        })
        .collect();
        assert_eq!(order, vec![2, 4, 3, 1]);
    }

    /// The task currently running in container 0 (test helper).
    fn p_busy_task(p: &ContainerPool) -> TaskId {
        match p.state(0) {
            ContainerState::Busy { task, .. } => task,
            other => panic!("container 0 not busy: {other:?}"),
        }
    }

    #[test]
    fn equal_priority_equal_deadline_ties_break_by_task_id() {
        let mut p = edge_pool(1);
        p.submit(img(0, 29.0), 0.0).unwrap();
        // Same created_ms/deadline → same rank up to the TaskId tie-break;
        // insertion order scrambled on purpose.
        for t in [7u64, 3, 9, 5] {
            assert!(p.submit(img(t, 29.0), 0.0).is_none());
        }
        let mut order = Vec::new();
        let mut running = p_busy_task(&p);
        while let Some(next) = p.complete(0, running, 10.0) {
            order.push(next.task.0);
            running = next.task;
        }
        assert_eq!(order, vec![3, 5, 7, 9]);
    }

    #[test]
    fn single_app_uniform_stream_queue_is_fifo() {
        // Legacy identity: one app, arrivals in time order → deadlines
        // ascend with arrival, so the priority queue reproduces FIFO.
        let mut p = edge_pool(1);
        for t in 0..6u64 {
            let mut f = img(t, 29.0);
            f.created_ms = t as f64 * 10.0;
            p.submit(f, f.created_ms);
        }
        let mut order = Vec::new();
        let mut running = p_busy_task(&p);
        while let Some(next) = p.complete(0, running, 100.0) {
            order.push(next.task.0);
            running = next.task;
        }
        assert_eq!(order, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn complete_empty_queue_returns_none() {
        let mut p = edge_pool(1);
        p.submit(img(1, 29.0), 0.0).unwrap();
        assert!(p.complete(0, TaskId(1), 223.0).is_none());
        assert_eq!(p.idle_count(), 1);
    }

    #[test]
    fn reset_clears_work_and_queue_keeps_capacity_and_load() {
        let mut p = edge_pool(2);
        p.set_bg_load(50.0);
        p.submit(img(1, 29.0), 0.0).unwrap();
        p.submit(img(2, 29.0), 0.0).unwrap();
        assert!(p.submit(img(3, 29.0), 0.0).is_none());
        p.reset();
        assert_eq!(p.busy_count(), 0);
        assert_eq!(p.queued_count(), 0);
        assert_eq!(p.warm_count(), 2);
        assert_eq!(p.bg_load(), 50.0);
        // Restarted pool accepts work again.
        assert!(p.submit(img(4, 29.0), 10.0).is_some());
    }

    #[test]
    fn completion_racing_reset_is_a_noop() {
        let mut p = edge_pool(1);
        p.submit(img(1, 29.0), 0.0).unwrap();
        assert!(p.submit(img(2, 29.0), 0.0).is_none());
        p.reset();
        // The worker for task 1 reports after the reset: nothing dispatched,
        // nothing panics, and the (cleared) queue stays empty.
        assert!(p.complete(0, TaskId(1), 223.0).is_none());
        assert_eq!(p.busy_count(), 0);
        assert_eq!(p.queued_count(), 0);
    }

    #[test]
    fn stale_completion_for_reassigned_container_is_a_noop() {
        // Live churn race: container 0 runs task 1, the node resets, task 3
        // is re-dispatched onto container 0 — then task 1's worker finally
        // reports. The stale completion must not free task 3's container.
        let mut p = edge_pool(1);
        p.submit(img(1, 29.0), 0.0).unwrap();
        p.reset();
        p.submit(img(3, 29.0), 10.0).unwrap();
        assert!(p.complete(0, TaskId(1), 400.0).is_none());
        assert_eq!(p.busy_count(), 1, "task 3 must keep its container");
        // The genuine completion still works.
        assert!(p.complete(0, TaskId(3), 500.0).is_none());
        assert_eq!(p.busy_count(), 0);
    }
}
