//! Container substrate: the lifecycle + timing model of the paper's Docker
//! face-detection containers.
//!
//! The paper's scheduler never sees inside a container — it sees *when
//! containers finish* under different concurrency, CPU load and image
//! sizes, which §IV measures exhaustively. [`ContainerPool`] reproduces
//! exactly those measured dynamics (calibration in
//! [`crate::profile::calibration`]): warm pools, FIFO `q_image` overflow
//! queues, per-dispatch contention, background-load slowdown, and the
//! prohibitive cold-start curve that justifies the paper's pre-warming.
//!
//! Virtual mode assigns durations from the model; live mode replaces the
//! duration source with real PJRT execution (see [`crate::live`]), reusing
//! the same pool bookkeeping.

pub mod pool;

pub use pool::{Assignment, ContainerPool, ContainerState, PoolStats, QueueDiscipline};
