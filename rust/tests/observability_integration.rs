//! Integration: the observability plane (DESIGN.md §Observability) —
//! seeded trace/timeline replay determinism, strict inertness of the
//! knobs on the run's comparable outputs, and the acceptance criterion
//! that a city flash crowd shows up as a met-fraction dip in the
//! timeline.

use edge_dds::experiments::{city_config, city_observed};
use edge_dds::metrics::trace::{shared, JsonlTrace, SharedBuf};
use edge_dds::metrics::writer::summary_json;
use edge_dds::metrics::{csv_line, TIMELINE_HEADER};
use edge_dds::net::FederationShape;
use edge_dds::sim::ScenarioBuilder;

/// One observed city run → (trace JSONL bytes, timeline CSV, report).
fn observed_city(seed: u64) -> (Vec<u8>, String, edge_dds::sim::RunReport) {
    let buf = SharedBuf::new();
    let sink = shared(JsonlTrace::new(Box::new(buf.clone())));
    let report = city_observed(seed, 8, 8, Some(sink), Some(1_000.0));
    let csv = report.timeline.as_ref().expect("timeline was enabled").to_csv();
    (buf.contents(), csv, report)
}

#[test]
fn same_seed_runs_emit_byte_identical_trace_and_timeline() {
    // The tentpole determinism claim: sim-time-stamped JSONL trace and
    // windowed CSV timeline replay byte-for-byte from the same seed.
    let (trace_a, csv_a, a) = observed_city(0x0B5);
    let (trace_b, csv_b, b) = observed_city(0x0B5);
    assert!(!trace_a.is_empty(), "observed run must emit trace events");
    assert_eq!(trace_a, trace_b, "trace JSONL must replay byte-identically");
    assert_eq!(csv_a, csv_b, "timeline CSV must replay byte-identically");
    assert_eq!(summary_json("obs", &a.summary), summary_json("obs", &b.summary));

    let text = String::from_utf8(trace_a).unwrap();
    for kind in ["admit", "place", "dispatch", "gossip_send", "gossip_apply"] {
        let needle = format!("\"kind\":\"{kind}\"");
        assert!(text.contains(&needle), "trace missing `{needle}`");
    }
    // Different seed ⇒ different trace (the sink sees real run data, not
    // a canned transcript).
    let (trace_c, _, _) = observed_city(0x0B6);
    assert_ne!(text.into_bytes(), trace_c);
}

#[test]
fn observability_knobs_leave_comparable_outputs_untouched() {
    // Inertness: turning every knob on must not change any output the
    // replay harness compares — summary JSON and per-task CSV lines.
    // (`events` is deliberately NOT compared: a timeline schedules
    // MetricsTick events, which exist only to sample.)
    let cfg = city_config(4, FederationShape::Hier { region_size: 2 }, 6);
    let plain = ScenarioBuilder::new(cfg.clone()).seed(9).run();
    assert!(plain.timeline.is_none() && plain.stage_ns.is_none());

    let buf = SharedBuf::new();
    let observed = ScenarioBuilder::new(cfg)
        .seed(9)
        .trace(shared(JsonlTrace::new(Box::new(buf.clone()))))
        .timeline(500.0)
        .stage_timing(true)
        .run();
    assert!(!buf.contents().is_empty());
    assert_eq!(
        summary_json("knobs", &plain.summary),
        summary_json("knobs", &observed.summary),
        "observability must not perturb the schedule"
    );
    let csv_plain: Vec<String> = plain.records.iter().map(csv_line).collect();
    let csv_obs: Vec<String> = observed.records.iter().map(csv_line).collect();
    assert_eq!(csv_plain, csv_obs);
    assert_eq!(plain.virtual_ms, observed.virtual_ms);

    // The side channels themselves: wall-clock stage histograms carry
    // real counts; the timeline accounts for every frame exactly once.
    let stage = observed.stage_ns.expect("stage timing was enabled");
    assert!(stage.contains("\"count\":"), "stage_ns JSON: {stage}");
    let tl = observed.timeline.expect("timeline was enabled");
    assert!(tl.to_csv().starts_with(TIMELINE_HEADER));
    let arrivals: usize = tl.rows().iter().map(|r| r.arrivals).sum();
    assert_eq!(arrivals, observed.summary.total);
}

#[test]
fn city_flash_crowd_dips_timeline_met_fraction() {
    // Acceptance criterion: the city's mid-run flash crowd must be
    // visible as a per-window met-fraction dip. The timeline's rows are
    // cross-checked against a direct per-record bucketing (outcomes
    // attributed to the frame's *arrival* window, so drops count
    // against the window that produced them).
    use edge_dds::core::Verdict;
    use std::collections::BTreeMap;

    let (_, _, report) = observed_city(0xF1A);
    let tl = report.timeline.as_ref().unwrap();
    let arrivals: usize = tl.rows().iter().map(|r| r.arrivals).sum();
    assert_eq!(arrivals, report.summary.total, "every frame lands in one window");
    let windows: std::collections::BTreeSet<u64> =
        tl.rows().iter().map(|r| r.window_start_ms as u64).collect();
    assert!(windows.len() >= 3, "city run too short to show a time-series");

    // Per-arrival-window (met, arrivals) over the whole city.
    let mut per_window: BTreeMap<u64, (usize, usize)> = BTreeMap::new();
    for r in &report.records {
        let w = (r.created_ms / 1_000.0) as u64;
        let e = per_window.entry(w).or_default();
        e.0 += usize::from(r.verdict == Verdict::Met);
        e.1 += 1;
    }
    // The flash crowd concentrates arrivals: windows must not be
    // uniformly loaded.
    let loads: Vec<usize> = per_window.values().map(|&(_, n)| n).collect();
    assert!(
        loads.iter().max() > loads.iter().min(),
        "diurnal + flash arrivals cannot be flat: {loads:?}"
    );
    // And the dip itself: unless the run was perfect (nothing to dip),
    // some window's met fraction must sit below some other window's.
    let fracs: Vec<f64> = per_window
        .values()
        .filter(|&&(_, n)| n >= 5)
        .map(|&(met, n)| met as f64 / n as f64)
        .collect();
    let min = fracs.iter().copied().fold(f64::INFINITY, f64::min);
    let max = fracs.iter().copied().fold(0.0_f64, f64::max);
    assert!(
        min < max || report.summary.met == report.summary.total,
        "failures exist but no window dips: fracs {fracs:?}, summary {:?}",
        report.summary
    );
}
