//! Integration: churn & failure injection (DESIGN.md §Churn) — seeded
//! replay determinism, DDS-vs-baseline degradation under edge failure,
//! federation behaviour when a whole cell churns, and a sim/live parity
//! smoke driving the live kill/restart hooks on the stub runtime.

use std::time::Duration;

use edge_dds::config::{ChurnEvent, ChurnKind, ChurnTarget, SystemConfig, WorkloadConfig};
use edge_dds::experiments::{apply_scenario, churn_config, ChurnScenario};
use edge_dds::live::LiveCluster;
use edge_dds::runtime::RuntimeService;
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::{ArrivalPattern, ScenarioBuilder};

fn wl(n: u32, interval: f64, deadline: f64) -> WorkloadConfig {
    WorkloadConfig {
        n_images: n,
        interval_ms: interval,
        size_kb: 29.0,
        size_jitter_kb: 0.0,
        deadline_ms: deadline,
        side_px: 64,
        pattern: ArrivalPattern::Uniform,
    }
}

/// A single-cell scenario whose worker device (index 1) fails mid-run and
/// recovers later.
fn worker_churn_cfg() -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Dds;
    cfg.churn.events = vec![
        ChurnEvent { at_ms: 900.0, target: ChurnTarget::Device(1), kind: ChurnKind::Fail },
        ChurnEvent { at_ms: 2_400.0, target: ChurnTarget::Device(1), kind: ChurnKind::Recover },
    ];
    cfg
}

#[test]
fn seeded_churn_replay_is_byte_identical() {
    // The acceptance bar: two runs of the same churn scenario with the
    // same seed produce identical RunSummary values (and record streams).
    let mk = || {
        ScenarioBuilder::new(worker_churn_cfg())
            .workload(wl(80, 50.0, 5_000.0))
            .seed(17)
            .run()
    };
    let (a, b) = (mk(), mk());
    assert_eq!(a.summary, b.summary);
    assert_eq!(a.records, b.records);
    assert_eq!(a.events, b.events);
    assert_eq!(a.virtual_ms, b.virtual_ms);
    // And churn visibly happened.
    assert!(a.summary.requeued > 0, "worker churn must requeue frames");
}

#[test]
fn seeded_random_churn_replay_is_deterministic_and_seed_sensitive() {
    let mk = |seed: u64| {
        let mut cfg = SystemConfig::default();
        cfg.policy = PolicyKind::Dds;
        cfg.churn.random = Some(edge_dds::config::RandomChurnConfig {
            device_mtbf_ms: 1_500.0,
            device_mttr_ms: 400.0,
        });
        ScenarioBuilder::new(cfg).workload(wl(100, 50.0, 2_000.0)).seed(seed).run()
    };
    let (a, b) = (mk(9), mk(9));
    assert_eq!(a.summary, b.summary);
    assert_eq!(a.records, b.records);
    assert_eq!(a.events, b.events);
    // A different seed draws a different churn trace; the run still
    // satisfies the accounting identity.
    let c = mk(10);
    assert_eq!(c.summary.met + c.summary.missed + c.summary.dropped, c.summary.total);
}

#[test]
fn dds_family_degrades_less_than_baselines_under_edge_failure() {
    // Edge down from 25% to 75% of the span: DDS devices detect the
    // silence and process locally; AOE/EODS keep streaming into the
    // void. Arrival is near local capacity (250 ms interval vs ~300 ms
    // service on two warm containers) so the fallback can absorb it.
    let run = |policy: PolicyKind| {
        let mut cfg = churn_config(1);
        cfg.policy = policy;
        apply_scenario(&mut cfg, ChurnScenario::EdgeFail, 120.0 * 250.0);
        ScenarioBuilder::new(cfg).workload(wl(120, 250.0, 5_000.0)).seed(3).run()
    };
    let dds = run(PolicyKind::Dds);
    let aoe = run(PolicyKind::Aoe);
    let eods = run(PolicyKind::Eods);
    assert!(
        dds.summary.met > aoe.summary.met,
        "dds {} must beat aoe {} under edge failure",
        dds.summary.met,
        aoe.summary.met
    );
    assert!(
        dds.summary.met >= eods.summary.met,
        "dds {} must not trail eods {} under edge failure",
        dds.summary.met,
        eods.summary.met
    );
    // The baselines lose frames outright; DDS mostly degrades to
    // missed-deadline rather than lost.
    assert!(aoe.summary.dropped > dds.summary.dropped);
}

#[test]
fn federation_survives_whole_cell_failure() {
    // 2 cells, per-cell cameras; cell 1's edge AND devices all fail
    // mid-run and recover. Cell 0 must keep meeting deadlines, and every
    // frame must stay accounted for.
    let mut cfg = churn_config(2);
    let span = 100.0 * 50.0;
    for (target, fail_at, back_at) in [
        (ChurnTarget::Edge(1), 0.3, 0.7),
        (ChurnTarget::Device(2), 0.3, 0.7),
        (ChurnTarget::Device(3), 0.3, 0.7),
    ] {
        cfg.churn.events.push(ChurnEvent {
            at_ms: fail_at * span,
            target,
            kind: ChurnKind::Fail,
        });
        cfg.churn.events.push(ChurnEvent {
            at_ms: back_at * span,
            target,
            kind: ChurnKind::Recover,
        });
    }
    let r = ScenarioBuilder::new(cfg).workload(wl(100, 50.0, 5_000.0)).seed(11).run();
    assert_eq!(r.summary.total, 200, "both cameras stream a full block");
    assert_eq!(
        r.summary.met + r.summary.missed + r.summary.dropped,
        200,
        "accounting identity under whole-cell churn"
    );
    assert!(r.summary.met > 0);
    // Cell 0's stream is unaffected by cell 1's death: most of its
    // frames complete. (Device ids depend only on the cell layout.)
    let layout = churn_config(2);
    let ids = ScenarioBuilder::device_ids(&layout);
    let cell0_completed = r
        .records
        .iter()
        .filter(|rec| rec.origin == ids[0] && rec.completed_ms.is_some())
        .count();
    assert!(cell0_completed > 50, "cell 0 must keep working: {cell0_completed}");
}

#[test]
fn mid_run_cell_join_contributes_capacity() {
    let mut cfg = churn_config(2);
    cfg.policy = PolicyKind::Dds;
    apply_scenario(&mut cfg, ChurnScenario::CellJoin, 100.0 * 50.0);
    let r = ScenarioBuilder::new(cfg).workload(wl(100, 50.0, 5_000.0)).seed(19).run();
    assert_eq!(r.summary.total, 200);
    assert_eq!(r.summary.met + r.summary.missed + r.summary.dropped, 200);
    // The joining cell's camera streams after its join: late frames exist
    // and complete.
    let late_completed = r
        .records
        .iter()
        .filter(|rec| rec.created_ms >= 0.40 * 5_000.0 && rec.completed_ms.is_some())
        .count();
    assert!(late_completed > 0, "joined cell must contribute completed frames");
}

/// Sim/live parity smoke under churn: the same single-cell config runs in
/// the simulator (scripted fail/recover events) and as a live socket
/// cluster on the stub runtime, where the worker device is killed and
/// restarted through the LiveCluster churn hooks. Live timing is
/// wall-clock, so met counts are not compared — the guarantee is the
/// *protocol*: detection, eviction, requeue and rejoin lose nothing.
#[test]
fn sim_live_parity_smoke_under_churn() {
    let mut cfg = worker_churn_cfg();
    cfg.workload = wl(30, 20.0, 2_000.0);

    let sim = ScenarioBuilder::new(cfg.clone()).run();
    assert_eq!(sim.summary.total, 30);
    assert_eq!(
        sim.summary.met + sim.summary.missed + sim.summary.dropped,
        30,
        "sim accounting identity under churn"
    );

    // Live: kill the worker (config index 1) mid-stream, restart it later.
    let cluster =
        LiveCluster::start(&cfg, RuntimeService::spawn_stub()).expect("live cluster start");
    std::thread::sleep(Duration::from_millis(200)); // joins + pings settle
    let streams = ScenarioBuilder::camera_streams(&cfg);
    for (idx, frames) in streams {
        cluster.stream_to(idx, frames).expect("stream");
    }
    std::thread::sleep(Duration::from_millis(150));
    cluster.fail_device(1).expect("fail hook");
    std::thread::sleep(Duration::from_millis(700));
    cluster.recover_device(1).expect("recover hook");
    let live = cluster.wait(Duration::from_secs(60));
    cluster.shutdown();

    assert_eq!(live.total, 30, "live cluster must see every frame");
    assert_eq!(
        live.met + live.missed + live.dropped,
        30,
        "live accounting identity under churn"
    );
    assert_eq!(
        live.dropped, 0,
        "worker churn must not lose frames: requeue covers the dead window"
    );
}
