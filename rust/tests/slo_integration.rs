//! Integration: the application-constraint subsystem (DESIGN.md
//! §Constraints & QoS) — per-app SLO tables for the mixed 3-app workload,
//! privacy enforcement under churn (including the requeue paths),
//! device-side requeue of frames awaiting a dead edge, legacy equivalence
//! of registry-less configs, and byte-identity of the per-app output
//! tables across seeded replays.

use edge_dds::config::SystemConfig;
use edge_dds::core::{AppId, Placement, PrivacyClass};
use edge_dds::experiments::{apply_scenario, slo_config, slo_run, ChurnScenario};
use edge_dds::metrics::writer::summary_json;
use edge_dds::metrics::{csv_line, TaskRecord};
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::ScenarioBuilder;

/// The 2-cell mixed-app scenario with per-cell worker churn injected.
fn churny_slo_cfg(policy: PolicyKind) -> SystemConfig {
    let mut cfg = slo_config(2, 40);
    cfg.policy = policy;
    let span = cfg.span_ms();
    apply_scenario(&mut cfg, ChurnScenario::DeviceChurn, span);
    cfg
}

fn assert_in_scope(rec: &TaskRecord, cfg: &SystemConfig) {
    let ids = ScenarioBuilder::device_ids(cfg);
    // Recompute each node's cell from the config-order device ids.
    let cell_of = |n: edge_dds::core::NodeId| -> Option<u32> {
        if let Some(pos) = ids.iter().position(|&d| d == n) {
            return Some(cfg.devices[pos].cell);
        }
        // Edge ids are the gaps: cell c's edge precedes its devices.
        let edges: Vec<edge_dds::core::NodeId> =
            ScenarioBuilder::new(cfg.clone()).topology().edges().collect();
        edges.iter().position(|&e| e == n).map(|c| c as u32)
    };
    match rec.privacy {
        PrivacyClass::Open => {}
        PrivacyClass::DeviceLocal => {
            assert_eq!(rec.placement, Placement::Local, "{:?} left its device", rec.task);
            if let Some(on) = rec.executed_on {
                assert_eq!(on, rec.origin, "{:?} executed off-device", rec.task);
            }
        }
        PrivacyClass::CellLocal => {
            assert!(
                !matches!(rec.placement, Placement::ToPeerEdge(_)),
                "{:?} crossed the backhaul",
                rec.task
            );
            if let Some(on) = rec.executed_on {
                assert_eq!(
                    cell_of(on),
                    cell_of(rec.origin),
                    "{:?} executed off-cell",
                    rec.task
                );
            }
        }
    }
}

#[test]
fn mixed_three_app_workload_reports_per_app_tables() {
    let row = slo_run(2, PolicyKind::Dds, false, 7, 40);
    assert_eq!(row.summary.per_app.len(), 3);
    assert_eq!(row.app_names, vec!["detector", "blur", "analytics"]);
    // Per-app rows partition the run: 2 cameras × (40 + 20 + 20).
    assert_eq!(row.summary.total, 2 * 80);
    let totals: Vec<usize> = row.summary.per_app.iter().map(|a| a.total).collect();
    assert_eq!(totals, vec![80, 40, 40]);
    assert_eq!(row.summary.privacy_violations, 0);
    // Every app completes work and reports latency percentiles.
    for a in &row.summary.per_app {
        assert!(a.met > 0, "app {} met nothing", a.app);
        let lat = a.latency.as_ref().expect("completed frames → latency summary");
        assert!(lat.p50 <= lat.p99);
    }
}

#[test]
fn privacy_never_violated_for_dds_even_under_churn() {
    // The acceptance bar: device_local / cell_local frames are never
    // observed off-device / off-cell — including the churn requeue paths.
    let cfg = churny_slo_cfg(PolicyKind::Dds);
    let r = ScenarioBuilder::new(cfg.clone()).seed(11).run();
    assert_eq!(r.summary.privacy_violations, 0, "DDS must never violate privacy");
    assert!(
        r.summary.requeued > 0,
        "worker churn must exercise the requeue path for the proof to bite"
    );
    for rec in &r.records {
        assert_eq!(rec.violations, 0);
        assert_in_scope(rec, &cfg);
    }
    // Accounting identity still holds under churn.
    assert_eq!(r.summary.met + r.summary.missed + r.summary.dropped, r.summary.total);
}

#[test]
fn privacy_holds_for_every_policy() {
    // Privacy is enforced by the node layer, not by policy goodwill: even
    // placement-blind baselines never ship a frame out of scope.
    for policy in PolicyKind::PAPER {
        let cfg = churny_slo_cfg(policy);
        let r = ScenarioBuilder::new(cfg.clone()).seed(3).run();
        assert_eq!(
            r.summary.privacy_violations, 0,
            "{policy}: privacy must hold for every policy"
        );
        for rec in &r.records {
            assert_in_scope(rec, &cfg);
        }
    }
}

#[test]
fn dds_meets_more_strict_deadlines_than_blind_baselines() {
    // The point of constraint-aware placement: under the mixed workload
    // the strict detector app must not do worse under DDS than under the
    // static parity split.
    let dds = slo_run(2, PolicyKind::Dds, false, 7, 40);
    let eods = slo_run(2, PolicyKind::Eods, false, 7, 40);
    let d = dds.summary.app(AppId(0)).unwrap().met;
    let e = eods.summary.app(AppId(0)).unwrap().met;
    assert!(d >= e, "dds detector met {d} must not trail eods {e}");
}

#[test]
fn device_side_requeue_resolves_frames_awaiting_dead_edge() {
    // ROADMAP follow-up: frames already forwarded to an edge that dies
    // must resolve via local fallback instead of hanging until run end.
    // Single cell, DDS, deadline low enough that the camera forwards a
    // steady share of frames; the edge fails mid-run and never recovers.
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Dds;
    cfg.workload.n_images = 60;
    cfg.workload.interval_ms = 100.0;
    cfg.workload.deadline_ms = 700.0; // < 2-container local service time under load
    cfg.churn.events.push(edge_dds::config::ChurnEvent {
        at_ms: 2_000.0,
        target: edge_dds::config::ChurnTarget::Edge(0),
        kind: edge_dds::config::ChurnKind::Fail,
    });
    let r = ScenarioBuilder::new(cfg).seed(5).run();
    assert_eq!(r.summary.total, 60);
    assert_eq!(r.summary.met + r.summary.missed + r.summary.dropped, 60);
    // Some frames were in flight toward the dead edge and came back.
    assert!(r.summary.requeued > 0, "expected device-side requeues");
    assert!(
        r.summary.replaced > 0,
        "requeued frames must complete via local fallback, not hang"
    );
    // Frames the dead edge swallowed do not linger as un-started drops
    // with a requeue marker: every requeued frame either completed or is
    // still accounted.
    let stranded = r
        .records
        .iter()
        .filter(|rec| rec.requeues > 0 && rec.completed_ms.is_none())
        .count();
    assert_eq!(stranded, 0, "device-side requeue must resolve stranded frames");
}

#[test]
fn registry_less_config_is_bit_identical_to_explicit_default_app() {
    // Acceptance: an absent [[app]] registry replays byte-identically to
    // the pre-registry single-app behaviour. The in-repo witnesses (no
    // pre-PR binary exists to diff against): (1) this test — a config
    // whose single [[app]] mirrors [workload] under the default
    // descriptor produces the *same* streams, records, summaries and
    // event counts as the registry-less config; (2) the wire tests prove
    // default-app frames encode byte-identically to the pre-registry
    // layout; (3) the stream-derivation test proves registry-less
    // camera_streams reproduce the historic frames; (4) fresh single-app
    // arrivals provably enqueue FIFO (pool unit test) — only churn
    // requeues / cross-cell forwards, which re-enter a non-empty queue,
    // dispatch differently (EDF-first, deliberately; see DESIGN.md §4c).
    let mut base = SystemConfig::default();
    base.policy = PolicyKind::Dds;
    base.workload.n_images = 80;
    base.workload.interval_ms = 50.0;
    base.workload.deadline_ms = 2_000.0;

    let mut explicit = base.clone();
    explicit.apps = vec![edge_dds::config::AppSpec::default_from_workload(&base.workload)];

    let sa = ScenarioBuilder::camera_streams(&base);
    let sb = ScenarioBuilder::camera_streams(&explicit);
    assert_eq!(sa, sb, "streams must be identical frame-for-frame");

    let ra = ScenarioBuilder::new(base).seed(9).run();
    let rb = ScenarioBuilder::new(explicit).seed(9).run();
    assert_eq!(ra.summary, rb.summary);
    assert_eq!(ra.records, rb.records);
    assert_eq!(ra.events, rb.events);
    assert_eq!(ra.virtual_ms, rb.virtual_ms);
    // And the textual outputs are byte-identical too.
    assert_eq!(
        summary_json("x", &ra.summary),
        summary_json("x", &rb.summary)
    );
    let la: Vec<String> = ra.records.iter().map(csv_line).collect();
    let lb: Vec<String> = rb.records.iter().map(csv_line).collect();
    assert_eq!(la, lb);
}

#[test]
fn seeded_slo_replay_is_byte_identical_including_per_app_tables() {
    // Satellite: the seeded-replay byte-identity bar extended to the new
    // per-app tables — two same-seed runs of the churny mixed workload
    // must serialize byte-for-byte equal CSV and JSON (per-app rows
    // included).
    let mk = || ScenarioBuilder::new(churny_slo_cfg(PolicyKind::Dds)).seed(17).run();
    let (a, b) = (mk(), mk());
    assert_eq!(a.summary, b.summary);
    assert_eq!(a.records, b.records);
    assert_eq!(a.events, b.events);
    let ja = summary_json("slo", &a.summary);
    let jb = summary_json("slo", &b.summary);
    assert_eq!(ja, jb);
    assert!(ja.contains(r#""apps":[{"app":0,"#), "per-app table must serialize");
    let ca: Vec<String> = a.records.iter().map(csv_line).collect();
    let cb: Vec<String> = b.records.iter().map(csv_line).collect();
    assert_eq!(ca, cb);
    // The CSV rows carry the app/privacy columns.
    assert!(ca.iter().any(|l| l.contains(",device_local,")));
    assert!(ca.iter().any(|l| l.contains(",cell_local,")));
}

#[test]
fn priority_app_preempts_best_effort_in_the_queue() {
    // Saturate a single cell hard enough that the pool queues: the strict
    // high-priority detector must end with a met fraction at least as
    // good as best-effort analytics' deadline-normalized share would
    // suggest — concretely, detector latency p50 stays below analytics'.
    let row = slo_run(1, PolicyKind::Dds, false, 13, 60);
    let det = row.summary.app(AppId(0)).unwrap();
    let ana = row.summary.app(AppId(2)).unwrap();
    let (Some(dl), Some(al)) = (det.latency.as_ref(), ana.latency.as_ref()) else {
        panic!("both apps must complete frames");
    };
    assert!(
        dl.p50 <= al.p50,
        "high-priority detector p50 {} must not exceed best-effort p50 {}",
        dl.p50,
        al.p50
    );
}
