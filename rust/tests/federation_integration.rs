//! Integration: the multi-cell federation — cross-cell forwarding, seeded
//! determinism, single-cell shim regression, and a sim/live parity smoke
//! test driven by the stub runtime (no artifacts or PJRT needed).

use std::time::Duration;

use edge_dds::config::{CellConfig, SystemConfig, WorkloadConfig};
use edge_dds::core::{NodeId, Placement};
use edge_dds::experiments::fed_config;
use edge_dds::live::LiveCluster;
use edge_dds::runtime::RuntimeService;
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::{ArrivalPattern, ImageStream, ScenarioBuilder};
use edge_dds::util::SplitMix64;

fn wl(n: u32, interval: f64, deadline: f64) -> WorkloadConfig {
    WorkloadConfig {
        n_images: n,
        interval_ms: interval,
        size_kb: 29.0,
        size_jitter_kb: 0.0,
        deadline_ms: deadline,
        side_px: 64,
        pattern: ArrivalPattern::Uniform,
    }
}

/// A stressed 2-cell scenario: all frames hit cell 0, whose edge carries
/// 100% background load (the Fig. 8 stress point), so DDS must shed work
/// over the backhaul to cell 1.
fn stressed_two_cells(n: u32) -> ScenarioBuilder {
    ScenarioBuilder::new(fed_config(2))
        .workload(wl(n, 30.0, 2_000.0))
        .edge_load(100.0)
        .seed(3)
}

#[test]
fn multi_cell_runs_end_to_end_and_forwards_across_cells() {
    let r = stressed_two_cells(300).run();
    assert_eq!(r.summary.total, 300);
    assert_eq!(r.summary.met + r.summary.missed + r.summary.dropped, 300);
    // Acceptance: DDS forwarded at least one image across cells …
    assert!(r.summary.forwarded > 0, "no cross-cell forwards under stress");
    // … and forwarded tasks actually executed in the peer cell (edge n3
    // or device n4/n5), with results attributed back to their records.
    let cross_executed = r
        .records
        .iter()
        .filter(|rec| {
            matches!(rec.placement, Placement::ToPeerEdge(_))
                && rec.executed_on.is_some_and(|n| n.0 >= 3)
        })
        .count();
    assert!(cross_executed > 0, "forwarded tasks must run in the peer cell");
    for rec in &r.records {
        if let Placement::ToPeerEdge(peer) = rec.placement {
            assert_eq!(peer, NodeId(3), "only one peer exists");
        }
    }
}

#[test]
fn federation_improves_deadline_satisfaction_under_stress() {
    let solo = ScenarioBuilder::new(fed_config(1))
        .workload(wl(300, 30.0, 2_000.0))
        .edge_load(100.0)
        .seed(3)
        .run();
    let fed = stressed_two_cells(300).run();
    assert!(
        fed.summary.met >= solo.summary.met,
        "federation must not hurt: {} vs {}",
        fed.summary.met,
        solo.summary.met
    );
}

#[test]
fn multi_cell_runs_are_deterministic() {
    // Two runs of the same multi-cell scenario with the same seed must
    // produce identical RunSummarys (and record streams).
    let a = stressed_two_cells(200).run();
    let b = stressed_two_cells(200).run();
    assert_eq!(a.summary, b.summary);
    assert_eq!(a.events, b.events);
    assert_eq!(a.records, b.records);
    // A different seed must change something observable (virtual time at
    // minimum — placements are load-dependent).
    let c = ScenarioBuilder::new(fed_config(2))
        .workload(wl(200, 30.0, 2_000.0))
        .edge_load(100.0)
        .seed(4)
        .run();
    assert_eq!(c.summary.total, 200);
}

#[test]
fn four_cell_scenario_spreads_work() {
    let r = ScenarioBuilder::new(fed_config(4))
        .workload(wl(200, 25.0, 2_000.0))
        .edge_load(100.0)
        .seed(9)
        .run();
    assert_eq!(r.summary.total, 200);
    assert!(r.summary.forwarded > 0);
    // Forward targets must all be edge servers (ids 0, 3, 6, 9).
    for rec in &r.records {
        if let Placement::ToPeerEdge(peer) = rec.placement {
            assert!(
                matches!(peer.0, 3 | 6 | 9),
                "forward target {peer} is not a peer edge"
            );
        }
    }
}

#[test]
fn shim_keeps_legacy_configs_unchanged() {
    // Regression guard for every pre-federation scenario: an empty
    // `cells` list must behave exactly like the explicit 1-cell form.
    let mk = |cells: Vec<CellConfig>| {
        let mut cfg = SystemConfig::default();
        cfg.policy = PolicyKind::Dds;
        cfg.cells = cells;
        ScenarioBuilder::new(cfg).workload(wl(100, 50.0, 2_000.0)).seed(21).run()
    };
    let legacy = mk(Vec::new());
    let explicit = mk(vec![CellConfig { warm_containers: 4, cpu_load_pct: 0.0 }]);
    assert_eq!(legacy.summary, explicit.summary);
    assert_eq!(legacy.records, explicit.records);
    assert_eq!(legacy.summary.forwarded, 0);
}

/// Sim/live parity smoke for the peer-edge decision: the same 2-cell
/// config runs in the simulator and as a live socket cluster (stub
/// runtime), and both must resolve every frame with the same accounting
/// identity. Live timing is wall-clock so met counts are not compared —
/// this guards the *protocol*: joins, gossip, forwards, and cross-cell
/// result relay all work over real sockets.
#[test]
fn sim_live_parity_smoke_two_cells() {
    let mut cfg = fed_config(2);
    // 20 frames every 5 ms with a 500 ms constraint: the paper-profile
    // predictor makes every device forward to the edge (597 ms predicted
    // > 500 budget), and cell 0's single edge container saturates, so the
    // simulator must take the peer-edge path.
    cfg.workload = wl(20, 5.0, 500.0);
    cfg.cells[0].warm_containers = 1;
    cfg.devices[0].warm_containers = 1;
    cfg.devices[1].warm_containers = 1;
    cfg.federation.gossip_period_ms = 25.0;

    let sim = ScenarioBuilder::new(cfg.clone()).run();
    assert_eq!(sim.summary.total, 20);
    assert_eq!(
        sim.summary.met + sim.summary.missed + sim.summary.dropped,
        20,
        "sim accounting identity"
    );
    assert!(sim.summary.forwarded > 0, "sim must exercise the peer-edge path");

    // The same config over real sockets with the stub runtime. Live
    // containers finish in sub-millisecond wall time, so placements
    // differ from the virtual run by design (DESIGN.md §Sim-vs-live) —
    // the smoke guarantee is the *protocol*: joins, gossip, forwards and
    // cross-cell result relay lose nothing end-to-end.
    let cluster =
        LiveCluster::start(&cfg, RuntimeService::spawn_stub()).expect("live cluster start");
    std::thread::sleep(Duration::from_millis(300)); // joins + gossip settle
    let camera = ScenarioBuilder::device_ids(&cfg)[0];
    let frames = ImageStream::new(cfg.workload, camera, SplitMix64::new(5)).generate();
    cluster.stream(frames).expect("stream");
    let live = cluster.wait(Duration::from_secs(60));
    cluster.shutdown();

    assert_eq!(live.total, 20, "live cluster must see every frame");
    assert_eq!(
        live.met + live.missed + live.dropped,
        20,
        "live accounting identity"
    );
    assert_eq!(live.dropped, 0, "nothing may be lost across the sockets");
}
