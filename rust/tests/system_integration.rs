//! Whole-system integration tests over the virtual engine: cross-module
//! behaviour, paper-shape assertions, failure injection, and property-style
//! randomized invariants (proptest is not in the offline crate set — cases
//! are generated with the deterministic SplitMix64 PRNG and failures print
//! the offending seed).

use edge_dds::sim::ArrivalPattern;
use edge_dds::config::{DeviceConfig, SystemConfig, WorkloadConfig};
use edge_dds::core::{NodeClass, NodeId, Placement, Verdict};
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::ScenarioBuilder;
use edge_dds::util::SplitMix64;

fn wl(n: u32, interval: f64, deadline: f64) -> WorkloadConfig {
    WorkloadConfig {
        n_images: n,
        interval_ms: interval,
        size_kb: 29.0,
        size_jitter_kb: 0.0,
        deadline_ms: deadline,
        side_px: 64,
            pattern: ArrivalPattern::Uniform,
    }
}

// ---------------------------------------------------------------------
// Paper-shape assertions (Figs. 5/6/8 headline claims).
// ---------------------------------------------------------------------

#[test]
fn distributed_beats_single_node_under_pressure() {
    // 50 imgs @50 ms, 2 s deadline (Fig. 5a regime).
    let b = ScenarioBuilder::paper_testbed(PolicyKind::Dds).workload(wl(50, 50.0, 2_000.0));
    let met = |p: PolicyKind| b.clone().policy(p).run().met();
    let (aor, aoe, eods, dds) = (
        met(PolicyKind::Aor),
        met(PolicyKind::Aoe),
        met(PolicyKind::Eods),
        met(PolicyKind::Dds),
    );
    assert!(dds > aor, "dds {dds} vs aor {aor}");
    assert!(eods > aor, "eods {eods} vs aor {aor}");
    assert!(aoe >= aor, "aoe {aoe} vs aor {aor}");
}

#[test]
fn min_feasible_constraint_about_200ms() {
    // The paper: below ~200 ms nothing is schedulable; at 500 ms the edge
    // can already serve some images.
    let b = ScenarioBuilder::paper_testbed(PolicyKind::Aoe).workload(wl(10, 500.0, 150.0));
    assert_eq!(b.run().met(), 0);
    let b = ScenarioBuilder::paper_testbed(PolicyKind::Aoe).workload(wl(10, 500.0, 500.0));
    assert!(b.run().met() > 0);
}

#[test]
fn adding_r2_improves_dds() {
    let wl1 = wl(500, 50.0, 5_000.0);
    let mut solo = SystemConfig::default();
    solo.policy = PolicyKind::Dds;
    solo.devices.truncate(1);
    let base = ScenarioBuilder::new(solo).workload(wl1).run().met();
    let ext = ScenarioBuilder::paper_testbed(PolicyKind::Dds).workload(wl1).run().met();
    assert!(ext > base, "R2 must raise met count: {ext} vs {base}");
}

#[test]
fn edge_load_degrades_throughput() {
    let wl1 = wl(300, 50.0, 5_000.0);
    let unloaded = ScenarioBuilder::paper_testbed(PolicyKind::Dds).workload(wl1).run().met();
    let loaded = ScenarioBuilder::paper_testbed(PolicyKind::Dds)
        .workload(wl1)
        .edge_load(100.0)
        .run()
        .met();
    assert!(loaded <= unloaded, "load can't help: {loaded} vs {unloaded}");
}

// ---------------------------------------------------------------------
// Failure injection.
// ---------------------------------------------------------------------

#[test]
fn udp_loss_drops_tasks_but_never_wedges() {
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Aoe; // every image crosses the lossy link
    cfg.network.loss_prob = 0.2;
    cfg.workload = wl(200, 50.0, 5_000.0);
    let r = ScenarioBuilder::new(cfg).run();
    assert_eq!(r.summary.total, 200);
    assert!(r.summary.dropped > 10, "20% loss must drop tasks: {}", r.summary.dropped);
    assert!(r.summary.dropped < 100, "loss rate should be ~20%: {}", r.summary.dropped);
    assert_eq!(
        r.summary.met + r.summary.missed + r.summary.dropped,
        200,
        "conservation of tasks"
    );
}

#[test]
fn full_loss_drops_everything_forwarded() {
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Aoe;
    cfg.network.loss_prob = 1.0;
    cfg.workload = wl(20, 50.0, 5_000.0);
    let r = ScenarioBuilder::new(cfg).run();
    assert_eq!(r.summary.dropped, 20);
    assert_eq!(r.summary.met, 0);
}

#[test]
fn heterogeneous_devices_still_schedulable() {
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Dds;
    cfg.devices = vec![
        DeviceConfig {
            class: NodeClass::RaspberryPi,
            warm_containers: 1,
            camera: true,
            cpu_load_pct: 50.0,
            location: (1.0, 0.0),
            battery: false,
            cell: 0,
        },
        DeviceConfig {
            class: NodeClass::SmartPhone,
            warm_containers: 2,
            camera: false,
            cpu_load_pct: 0.0,
            location: (2.0, 0.0),
            battery: false,
            cell: 0,
        },
        DeviceConfig {
            class: NodeClass::RaspberryPi,
            warm_containers: 3,
            camera: false,
            cpu_load_pct: 25.0,
            location: (3.0, 0.0),
            battery: false,
            cell: 0,
        },
    ];
    cfg.workload = wl(100, 50.0, 5_000.0);
    let r = ScenarioBuilder::new(cfg).run();
    assert_eq!(r.summary.total, 100);
    assert!(r.summary.met > 50, "heterogeneous cluster should serve most: {}", r.summary.met);
}

// ---------------------------------------------------------------------
// Property-style randomized invariants.
// ---------------------------------------------------------------------

/// Every task is created exactly once and ends in exactly one verdict;
/// completed tasks have consistent timestamps; placements are legal.
#[test]
fn prop_task_conservation_and_timestamps() {
    let mut rng = SplitMix64::new(0xE2E);
    for case in 0..25 {
        let seed = rng.next_u64();
        let policy = PolicyKind::ALL[rng.choice_index(PolicyKind::ALL.len())];
        let n = 20 + rng.randint(0, 80) as u32;
        let interval = [20.0, 50.0, 100.0, 250.0][rng.choice_index(4)];
        let deadline = [300.0, 1_000.0, 5_000.0, 30_000.0][rng.choice_index(4)];
        let loss = [0.0, 0.0, 0.05][rng.choice_index(3)];

        let mut cfg = SystemConfig::default();
        cfg.policy = policy;
        cfg.seed = seed;
        cfg.network.loss_prob = loss;
        cfg.workload = wl(n, interval, deadline);
        let r = ScenarioBuilder::new(cfg).run();
        let ctx = format!("case {case}: seed={seed} policy={policy} n={n} interval={interval} deadline={deadline} loss={loss}");

        assert_eq!(r.summary.total, n as usize, "{ctx}");
        assert_eq!(
            r.summary.met + r.summary.missed + r.summary.dropped,
            n as usize,
            "{ctx}"
        );
        assert_eq!(r.records.len(), n as usize, "{ctx}");
        for rec in &r.records {
            match rec.verdict {
                Verdict::Met | Verdict::Missed => {
                    let done = rec.completed_ms.expect("completed has timestamp");
                    assert!(done >= rec.created_ms, "{ctx}: time goes forward");
                    let started = rec.started_ms.expect("completed has start");
                    assert!(started + 1e-9 >= rec.created_ms, "{ctx}");
                    assert!(rec.process_ms.unwrap() > 0.0, "{ctx}");
                    let e2e = rec.e2e_ms().unwrap();
                    match rec.verdict {
                        Verdict::Met => assert!(e2e <= rec.deadline_ms + 1e-9, "{ctx}"),
                        Verdict::Missed => assert!(e2e > rec.deadline_ms, "{ctx}"),
                        _ => unreachable!(),
                    }
                }
                Verdict::Dropped => {
                    assert!(loss > 0.0, "{ctx}: lossless nets must not drop");
                }
            }
            // Legal placements only.
            match rec.placement {
                Placement::Local | Placement::ToEdge => {}
                Placement::Offload(node) => {
                    assert_ne!(node, rec.origin, "{ctx}: offload target != origin");
                    assert_ne!(node, NodeId(0), "{ctx}: offload target is a device");
                }
                Placement::ToPeerEdge(peer) => {
                    panic!("{ctx}: single-cell run forwarded to {peer}");
                }
            }
        }
    }
}

/// AOR must never execute anywhere but the origin; AOE never at it.
#[test]
fn prop_policy_placement_contracts() {
    let mut rng = SplitMix64::new(0xC0FFEE);
    for _ in 0..10 {
        let n = 10 + rng.randint(0, 40) as u32;
        let interval = [20.0, 100.0][rng.choice_index(2)];
        let b = ScenarioBuilder::paper_testbed(PolicyKind::Aor)
            .workload(wl(n, interval, 10_000.0))
            .seed(rng.next_u64());
        for rec in b.run().records {
            assert_eq!(rec.executed_on, Some(rec.origin), "AOR stays local");
        }
        let b = ScenarioBuilder::paper_testbed(PolicyKind::Aoe)
            .workload(wl(n, interval, 10_000.0))
            .seed(rng.next_u64());
        for rec in b.run().records {
            assert_eq!(rec.executed_on, Some(NodeId(0)), "AOE runs at the edge");
        }
    }
}

/// EODS: odd sequence numbers stay at the origin, even go to the edge.
#[test]
fn prop_eods_parity() {
    let b = ScenarioBuilder::paper_testbed(PolicyKind::Eods).workload(wl(40, 100.0, 60_000.0));
    for rec in b.run().records {
        let expect = if rec.task.0 % 2 == 1 { Some(rec.origin) } else { Some(NodeId(0)) };
        assert_eq!(rec.executed_on, expect, "task {}", rec.task.0);
    }
}

/// Determinism: identical configs produce identical record streams.
#[test]
fn prop_bitwise_determinism() {
    let mut rng = SplitMix64::new(77);
    for _ in 0..5 {
        let seed = rng.next_u64();
        let policy = PolicyKind::ALL[rng.choice_index(PolicyKind::ALL.len())];
        let mk = || {
            let mut cfg = SystemConfig::default();
            cfg.policy = policy;
            cfg.seed = seed;
            cfg.network.loss_prob = 0.05;
            cfg.workload = wl(60, 50.0, 3_000.0);
            ScenarioBuilder::new(cfg).run()
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.records.len(), b.records.len());
        for (ra, rb) in a.records.iter().zip(b.records.iter()) {
            assert_eq!(ra, rb, "seed {seed} policy {policy}");
        }
    }
}

/// The engine never goes back in time and never loses events.
#[test]
fn prop_virtual_time_monotone() {
    let mut rng = SplitMix64::new(123);
    for _ in 0..10 {
        let mut cfg = SystemConfig::default();
        cfg.policy = PolicyKind::Dds;
        cfg.seed = rng.next_u64();
        cfg.workload = wl(50, 30.0, 2_000.0);
        let r = ScenarioBuilder::new(cfg).run();
        assert!(r.virtual_ms.is_finite() && r.virtual_ms >= 0.0);
        assert!(r.events > 0);
        // Completion times never precede start times.
        for rec in &r.records {
            if let (Some(s), Some(c)) = (rec.started_ms, rec.completed_ms) {
                assert!(c + 1e-9 >= s);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Energy extension (paper §VI future work).
// ---------------------------------------------------------------------

fn battery_testbed(policy: PolicyKind) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.policy = policy;
    // R1 (camera, mains) + R2 (battery-powered helper).
    cfg.devices[1].battery = true;
    cfg
}

#[test]
fn batteries_drain_when_offloaded_to() {
    let mut cfg = battery_testbed(PolicyKind::Dds);
    cfg.workload = wl(500, 50.0, 5_000.0);
    let r = ScenarioBuilder::new(cfg).run();
    assert_eq!(r.batteries.len(), 1, "one battery-powered device");
    let (node, pct, consumed) = r.batteries[0];
    assert_eq!(node, NodeId(2));
    assert!(pct < 100.0, "battery must drain: {pct}%");
    assert!(consumed > 0.0);
}

#[test]
fn dds_energy_spares_battery_devices() {
    let mut cfg = battery_testbed(PolicyKind::Dds);
    cfg.workload = wl(500, 50.0, 5_000.0);
    let plain = ScenarioBuilder::new(cfg).run();

    let mut cfg = battery_testbed(PolicyKind::DdsEnergy);
    cfg.workload = wl(500, 50.0, 5_000.0);
    let energy = ScenarioBuilder::new(cfg).run();

    let consumed = |r: &edge_dds::sim::RunReport| r.batteries[0].2;
    // Both policies may use R2 (it is above the 20% reserve the whole
    // run), but dds-energy must not consume *more*, and both must still
    // schedule successfully.
    assert!(consumed(&energy) <= consumed(&plain) + 1e-9,
        "energy {} vs plain {}", consumed(&energy), consumed(&plain));
    assert!(energy.met() > 0);
}

#[test]
fn depleted_device_forwards_everything() {
    // Give R1 (the camera) a battery and run a stream long enough that an
    // artificially tiny pack empties: once depleted, every frame goes to
    // the edge. We emulate depletion by checking behaviour via policy:
    // a dds-energy device below reserve forwards even feasible work.
    use edge_dds::core::{Constraint, ImageMeta, TaskId};
    use edge_dds::profile::{profile_for, Predictor};
    use edge_dds::scheduler::{DeviceCtx, LocalSnapshot, SchedulerPolicy};

    let mut policy = PolicyKind::DdsEnergy.build(1);
    let img = ImageMeta {
        task: TaskId(1),
        origin: NodeId(1),
        size_kb: 29.0,
        side_px: 64,
        created_ms: 0.0,
        constraint: Constraint::deadline(1e9), // trivially feasible locally
        seq: 1,
    };
    let pred = Predictor::new(profile_for(NodeClass::RaspberryPi));
    let mk = |batt: Option<f64>| LocalSnapshot {
        node: NodeId(1),
        busy_containers: 0,
        warm_containers: 2,
        queued_images: 0,
        cpu_load_pct: 0.0,
        battery_pct: batt,
    };
    // Healthy battery: local (time feasible).
    let ctx = DeviceCtx {
        now_ms: 0.0,
        img: &img,
        local: mk(Some(80.0)),
        predictor: &pred,
        edge_suspected: false,
    };
    assert_eq!(policy.decide_device(&ctx), Placement::Local);
    // Below the 20% reserve: conserve → forward.
    let ctx = DeviceCtx {
        now_ms: 0.0,
        img: &img,
        local: mk(Some(10.0)),
        predictor: &pred,
        edge_suspected: false,
    };
    assert_eq!(policy.decide_device(&ctx), Placement::ToEdge);
    // Mains-powered: unaffected.
    let ctx = DeviceCtx {
        now_ms: 0.0,
        img: &img,
        local: mk(None),
        predictor: &pred,
        edge_suspected: false,
    };
    assert_eq!(policy.decide_device(&ctx), Placement::Local);
}

#[test]
fn dds_energy_behaves_like_dds_without_batteries() {
    // On the all-mains paper testbed the energy policy must degenerate to
    // plain DDS (same met counts).
    let wl1 = wl(200, 50.0, 5_000.0);
    let dds = ScenarioBuilder::paper_testbed(PolicyKind::Dds).workload(wl1).run();
    let ene = ScenarioBuilder::paper_testbed(PolicyKind::DdsEnergy).workload(wl1).run();
    assert_eq!(dds.met(), ene.met());
    assert!(ene.batteries.is_empty());
}

// ---------------------------------------------------------------------
// Arrival-process extension.
// ---------------------------------------------------------------------

#[test]
fn arrival_patterns_run_and_order_sensibly() {
    use edge_dds::sim::ArrivalPattern;
    let mut met = std::collections::HashMap::new();
    for (name, pattern) in [
        ("uniform", ArrivalPattern::Uniform),
        ("poisson", ArrivalPattern::Poisson),
        ("bursty", ArrivalPattern::Bursty { burst: 10 }),
    ] {
        let mut cfg = SystemConfig::default();
        cfg.policy = PolicyKind::Dds;
        cfg.workload = wl(300, 50.0, 3_000.0);
        cfg.workload.pattern = pattern;
        let r = ScenarioBuilder::new(cfg).run();
        assert_eq!(r.summary.total, 300, "{name}");
        assert_eq!(
            r.summary.met + r.summary.missed + r.summary.dropped,
            300,
            "{name}"
        );
        met.insert(name, r.summary.met);
    }
    // Bursty traffic stresses queues: it must not beat smooth arrivals.
    assert!(met["bursty"] <= met["uniform"] + 10, "{met:?}");
}
