//! Integration: city-scale federation (DESIGN.md §Hierarchical gossip) —
//! seeded 64-cell replay determinism, regional-gossip equivalence with
//! classic placement on a degenerate single-region city, and
//! incremental-vs-rebuilt candidate-snapshot equality under churn.

use edge_dds::config::{ChurnEvent, ChurnKind, ChurnTarget};
use edge_dds::experiments::city_config;
use edge_dds::metrics::csv_line;
use edge_dds::metrics::writer::summary_json;
use edge_dds::net::FederationShape;
use edge_dds::sim::ScenarioBuilder;

#[test]
fn seeded_city_run_replays_byte_identically() {
    // The headline determinism claim: a 64-cell hierarchical city —
    // regional gossip, diurnal + flash-crowd arrivals, mixed districts —
    // replays byte-identical CSV and JSON from the same seed.
    let cfg = city_config(64, FederationShape::Hier { region_size: 8 }, 4);
    let run = || ScenarioBuilder::new(cfg.clone()).seed(0xC17).run();
    let (a, b) = (run(), run());
    // 64 cameras × (4 diurnal + 2 flash + 2 batch) frames.
    assert_eq!(a.summary.total, 64 * 8);
    assert_eq!(a.summary.met + a.summary.missed + a.summary.dropped, a.summary.total);
    assert_eq!(a.summary.privacy_violations, 0, "cell_local flash frames must not leak");
    assert!(a.summary.forwarded > 0, "downtown cells must overflow across the backhaul");
    assert!(a.summary.gossip_bytes.values().sum::<u64>() > 0);
    assert_eq!(summary_json("city", &a.summary), summary_json("city", &b.summary));
    let csv_a: Vec<String> = a.records.iter().map(csv_line).collect();
    let csv_b: Vec<String> = b.records.iter().map(csv_line).collect();
    assert_eq!(csv_a, csv_b);
    assert_eq!(a.virtual_ms, b.virtual_ms);
    assert_eq!(a.events, b.events);
}

#[test]
fn single_region_hier_matches_classic_mesh_placement() {
    // Degenerate hierarchy: one region spanning the whole city makes the
    // hier wiring a full mesh, and regional gossip degenerates to "own
    // summary to every neighbor". Classic mesh gossip additionally sends
    // damped relays — but in a full mesh every receiver already holds a
    // same-tick direct copy, so freshest-wins (ties broken toward fewer
    // hops) rejects every relay and both modes converge to identical peer
    // tables at identical times. Placement must therefore be identical;
    // only the bytes moved differ (that is the aggregation's whole point).
    let one = |shape| {
        let mut cfg = city_config(8, shape, 8);
        cfg.federation.max_forward_hops = 1;
        ScenarioBuilder::new(cfg).seed(11).run()
    };
    let classic = one(FederationShape::Mesh);
    let regional = one(FederationShape::Hier { region_size: 8 });
    assert!(
        regional.summary.gossip_bytes.values().sum::<u64>()
            < classic.summary.gossip_bytes.values().sum::<u64>(),
        "regional gossip must move fewer bytes than classic relaying"
    );
    let mut c = classic.summary.clone();
    let mut r = regional.summary.clone();
    // Gossip metering is the one intended difference; everything else —
    // placements, latencies, per-app rows, hop counters — must match.
    c.gossip_bytes = Default::default();
    r.gossip_bytes = Default::default();
    assert_eq!(c, r);
    let csv_c: Vec<String> = classic.records.iter().map(csv_line).collect();
    let csv_r: Vec<String> = regional.records.iter().map(csv_line).collect();
    assert_eq!(csv_c, csv_r);
}

#[test]
fn incremental_snapshots_match_full_rebuilds_under_churn() {
    // The PR-4 candidate-snapshot cache, now maintained by in-place
    // deltas: a run with incremental maintenance must place every frame
    // exactly as a run that rebuilds from scratch on every version bump.
    // Scripted churn forces the structural-change fallback (devices leave
    // and rejoin the MP table) on top of the steady delta stream.
    let mut cfg = city_config(4, FederationShape::Hier { region_size: 2 }, 10);
    cfg.churn.events = vec![
        ChurnEvent { at_ms: 800.0, target: ChurnTarget::Device(1), kind: ChurnKind::Fail },
        ChurnEvent { at_ms: 2_000.0, target: ChurnTarget::Device(1), kind: ChurnKind::Recover },
        ChurnEvent { at_ms: 1_200.0, target: ChurnTarget::Device(3), kind: ChurnKind::Fail },
        ChurnEvent { at_ms: 2_600.0, target: ChurnTarget::Device(3), kind: ChurnKind::Recover },
    ];
    let run = |incremental: bool| {
        let mut eng = ScenarioBuilder::new(cfg.clone()).seed(42).build();
        eng.set_snapshot_incremental(incremental);
        eng.run();
        let (rebuilds, reuses, deltas) = eng.snapshot_counters();
        let summary = eng.recorder.summarize();
        let csv: Vec<String> = eng.recorder.records().iter().map(csv_line).collect();
        (summary, csv, rebuilds, reuses, deltas)
    };
    let (inc_sum, inc_csv, _, _, inc_deltas) = run(true);
    let (full_sum, full_csv, full_rebuilds, _, full_deltas) = run(false);
    assert!(inc_deltas > 0, "churning city must exercise the delta path");
    assert_eq!(full_deltas, 0, "rebuild mode must never patch in place");
    assert!(full_rebuilds > 1, "rebuild mode rebuilds on every version bump");
    assert_eq!(inc_sum, full_sum);
    assert_eq!(inc_csv, full_csv);
}
