//! Integration: the live socket deployment (threads + TCP + PJRT).
//! Requires `make artifacts`; skips gracefully otherwise.

use std::time::Duration;

use edge_dds::sim::ArrivalPattern;
use edge_dds::config::{SystemConfig, WorkloadConfig};
use edge_dds::core::NodeId;
use edge_dds::live::LiveCluster;
use edge_dds::runtime::RuntimeService;
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::ImageStream;
use edge_dds::util::SplitMix64;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("face_64.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn small_workload(n: u32) -> WorkloadConfig {
    WorkloadConfig {
        n_images: n,
        interval_ms: 40.0,
        size_kb: 29.0,
        size_jitter_kb: 0.0,
        deadline_ms: 10_000.0,
        side_px: 64,
            pattern: ArrivalPattern::Uniform,
    }
}

#[test]
fn live_cluster_serves_stream_dds() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Dds;
    cfg.workload = small_workload(12);

    let cluster =
        LiveCluster::start(&cfg, RuntimeService::spawn(&dir).expect("spawn")).expect("start");
    std::thread::sleep(Duration::from_millis(300)); // joins + profiles settle

    let frames = ImageStream::new(cfg.workload, NodeId(1), SplitMix64::new(5)).generate();
    cluster.stream(frames).expect("stream");
    let summary = cluster.wait(Duration::from_secs(90));
    cluster.shutdown();

    assert_eq!(summary.total, 12);
    assert_eq!(summary.met + summary.missed + summary.dropped, 12);
    // Localhost + 64px model (a few ms per image): everything should land
    // well inside 10 s.
    assert!(summary.met >= 10, "live met {}/12", summary.met);
    let lat = summary.latency.expect("completed tasks");
    assert!(lat.mean > 0.0 && lat.mean < 10_000.0);
    let proc = summary.process.expect("process times recorded");
    assert!(proc.mean > 0.0, "PJRT execution must take measurable time");
}

#[test]
fn introspection_endpoint_serves_metrics() {
    // Stub runtime: no artifacts needed — the endpoint reads node state,
    // not model outputs.
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Dds;
    cfg.workload = small_workload(4);
    let cluster = LiveCluster::start(&cfg, RuntimeService::spawn_stub()).expect("start");
    std::thread::sleep(Duration::from_millis(200));

    let addrs = cluster.introspect_addrs().to_vec();
    assert_eq!(addrs.len(), 1, "single-cell config serves one endpoint");
    let (edge, addr) = addrs[0];
    use std::io::Read;
    let mut text = String::new();
    std::net::TcpStream::connect(addr)
        .expect("connect to introspection endpoint")
        .read_to_string(&mut text)
        .expect("read exposition");
    cluster.shutdown();

    assert!(text.starts_with("HTTP/1.0 200 OK"), "got: {text}");
    let body = text.split("\r\n\r\n").nth(1).expect("exposition body");
    let needle = format!("edge_queue_depth{{node=\"{edge}\"}} ");
    assert!(body.contains(&needle), "missing `{needle}` in:\n{body}");
    for metric in [
        "edge_busy_containers",
        "edge_warm_containers",
        "edge_mp_entries",
        "edge_peer_entries",
        "edge_peer_max_staleness_ms",
        "pool_buf_hits",
        "pool_buf_misses",
    ] {
        assert!(body.contains(metric), "missing `{metric}` in:\n{body}");
    }
}

#[test]
fn live_observability_produces_trace_and_timeline() {
    use edge_dds::live::LiveObservability;
    use edge_dds::metrics::trace::{shared, JsonlTrace, SharedBuf};
    use edge_dds::sim::ScenarioBuilder;

    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Dds;
    cfg.workload = small_workload(6);
    let buf = SharedBuf::new();
    let obs = LiveObservability {
        trace: Some(shared(JsonlTrace::new(Box::new(buf.clone())))),
        timeline_window_ms: Some(100.0),
    };
    let cluster =
        LiveCluster::start_observed(&cfg, RuntimeService::spawn_stub(), obs).expect("start");
    std::thread::sleep(Duration::from_millis(200));
    for (i, frames) in ScenarioBuilder::camera_streams(&cfg) {
        cluster.stream_to(i, frames).expect("stream");
    }
    let summary = cluster.wait(Duration::from_secs(60));
    let timeline = cluster.take_timeline().expect("timeline was enabled");
    cluster.shutdown();

    assert_eq!(summary.total, 6);
    let text = String::from_utf8(buf.contents()).unwrap();
    assert!(text.contains(r#""kind":"admit""#), "trace missing admits:\n{text}");
    assert!(text.contains(r#""kind":"place""#), "trace missing places:\n{text}");
    assert!(text.contains(r#""kind":"dispatch""#), "trace missing dispatches:\n{text}");
    let csv = timeline.to_csv();
    assert!(csv.starts_with(edge_dds::metrics::TIMELINE_HEADER));
    let arrivals: usize = timeline.rows().iter().map(|r| r.arrivals).sum();
    assert_eq!(arrivals, 6, "every frame lands in some window:\n{csv}");
}

#[test]
fn live_cluster_aoe_routes_to_edge() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Aoe;
    cfg.workload = small_workload(6);

    let cluster =
        LiveCluster::start(&cfg, RuntimeService::spawn(&dir).expect("spawn")).expect("start");
    std::thread::sleep(Duration::from_millis(300));
    let frames = ImageStream::new(cfg.workload, NodeId(1), SplitMix64::new(6)).generate();
    cluster.stream(frames).expect("stream");
    let summary = cluster.wait(Duration::from_secs(60));
    cluster.shutdown();

    assert_eq!(summary.total, 6);
    assert!(summary.met >= 5, "AOE on localhost should meet ~all: {}", summary.met);
    // AOE executes everything at the edge → local fraction 0.
    assert_eq!(summary.local_fraction, 0.0);
}
