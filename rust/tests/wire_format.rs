//! Wire-format coverage: exhaustive roundtrips over every message tag
//! (0x01–0x0B) — including the versioned app/privacy/priority constraint
//! descriptor — plus corrupted/truncated-frame rejection (a malformed
//! frame must yield a decode error, never a panic) and a legacy-decode
//! proof that pre-registry frames decode as the default app.
//!
//! The borrowed decode surface ([`view`], DESIGN.md §9) is held to strict
//! parity with the owned path on the same inputs: identical messages on
//! success, errors on exactly the same malformed frames.

use edge_dds::core::message::{EdgeSummary, ForwardRoute, ProfileUpdate, UserRequest};
use edge_dds::core::wire::{decode, encode, encode_append, encoded_len, read_frame, view, MessageView};
use edge_dds::core::{AppId, Constraint, ImageMeta, Message, NodeId, PrivacyClass, TaskId};

fn sample_image(task: u64) -> ImageMeta {
    ImageMeta {
        task: TaskId(task),
        origin: NodeId(1),
        size_kb: 29.5,
        side_px: 128,
        created_ms: 42.25,
        constraint: Constraint::pinned(2_500.0, NodeId(3)),
        seq: task,
    }
}

fn app_image(task: u64, privacy: PrivacyClass) -> ImageMeta {
    let mut m = sample_image(task);
    // Pinned *and* descriptor sections together — both flag bits set.
    m.constraint = Constraint {
        pinned_node: Some(NodeId(3)),
        ..Constraint::for_app(AppId(2), 750.0, privacy, 3)
    };
    m
}

/// One representative message per wire tag, covering every variant and
/// both Option branches where one exists.
fn all_messages() -> Vec<Message> {
    vec![
        // 0x01
        Message::User(UserRequest {
            app_id: 7,
            location: (-3.5, 12.25),
            constraint: Constraint::deadline(5_000.0),
            n_images: 50,
            interval_ms: 100.0,
        }),
        // 0x02
        Message::Activate {
            request: UserRequest {
                app_id: 1,
                location: (0.0, 0.0),
                constraint: Constraint::pinned(100.0, NodeId(2)),
                n_images: 10,
                interval_ms: 50.0,
            },
            reply_to: NodeId(0),
        },
        // 0x03
        Message::Image(sample_image(99)),
        // 0x04
        Message::Result {
            task: TaskId(99),
            processed_by: NodeId(2),
            detections: 4,
            max_score: 1.25,
            process_ms: 223.0,
        },
        // 0x05, battery Some
        Message::Profile(ProfileUpdate {
            node: NodeId(2),
            busy_containers: 1,
            warm_containers: 3,
            queued_images: 5,
            cpu_load_pct: 42.5,
            battery_pct: Some(88.0),
            sent_ms: 2_000.0,
        }),
        // 0x05, battery None
        Message::Profile(ProfileUpdate {
            node: NodeId(4),
            busy_containers: 0,
            warm_containers: 2,
            queued_images: 0,
            cpu_load_pct: 0.0,
            battery_pct: None,
            sent_ms: 60.0,
        }),
        // 0x06
        Message::Join { node: NodeId(5), class_tag: 2, warm_containers: 2 },
        // 0x07
        Message::JoinAck { assigned: NodeId(5) },
        // 0x03 again: full app descriptor (every privacy class appears
        // across the set; pinned + descriptor coexist in sample_image's
        // pinned base via app_image).
        Message::Image(app_image(100, PrivacyClass::DeviceLocal)),
        Message::Image(app_image(101, PrivacyClass::CellLocal)),
        // 0x08 (legacy/default route — the versioned routing section has
        // its own test: a strict prefix of a routed frame is *valid by
        // design*, so it cannot join the every-truncation-fails sweep).
        Message::Forward {
            img: sample_image(12),
            from_edge: NodeId(0),
            route: ForwardRoute::default(),
        },
        // 0x08 with descriptor (open + non-default app/priority).
        Message::Forward {
            img: app_image(102, PrivacyClass::Open),
            from_edge: NodeId(0),
            route: ForwardRoute::default(),
        },
        // 0x09 (direct summary — same reasoning as 0x08).
        Message::EdgeSummary(EdgeSummary {
            edge: NodeId(3),
            busy_containers: 2,
            warm_containers: 4,
            queued_images: 1,
            cpu_load_pct: 50.0,
            device_idle_containers: 5,
            sent_ms: 123.0,
            hops: 0,
            via: NodeId(3),
        }),
        // 0x0A
        Message::Ping { from: NodeId(0), sent_ms: 4_250.5 },
        // 0x0B (flag-versioned: the leading CLOUD_FLAGS_V1 byte is
        // all-zero today; full descriptor + pinned constraint aboard).
        Message::CloudOffload {
            img: app_image(103, PrivacyClass::Open),
            from_edge: NodeId(0),
        },
    ]
}

#[test]
fn roundtrip_every_tag() {
    let msgs = all_messages();
    // The sample set covers every tag exactly once (0x05 twice for the
    // two Option branches).
    let mut tags: Vec<u8> = msgs.iter().map(|m| m.tag()).collect();
    tags.sort_unstable();
    tags.dedup();
    assert_eq!(tags, (0x01..=0x0B).collect::<Vec<u8>>(), "a wire tag is untested");

    let mut buf = Vec::new();
    for msg in msgs {
        let n = encode(&msg, &mut buf);
        assert_eq!(n, buf.len());
        let got = decode(&buf).expect("roundtrip decode");
        assert_eq!(got, msg);
    }
}

#[test]
fn view_matches_owned_decode_for_every_tag() {
    let msgs = all_messages();
    // Coverage guard: the parity sweep must exercise every wire tag.
    let mut tags: Vec<u8> = msgs.iter().map(|m| m.tag()).collect();
    tags.sort_unstable();
    tags.dedup();
    assert_eq!(tags, (0x01..=0x0B).collect::<Vec<u8>>(), "a wire tag is untested");

    let mut buf = Vec::new();
    for msg in msgs {
        encode(&msg, &mut buf);
        let v = view(&buf).expect("view must accept every encodable frame");
        assert_eq!(v.tag(), buf[0]);
        assert_eq!(v.to_owned(), msg, "view::to_owned must equal the original");
        assert_eq!(v.to_owned(), decode(&buf).unwrap(), "view and decode must agree");
    }
}

#[test]
fn view_borrows_the_visited_path_without_copying() {
    // The only heap-backed wire field is Forward's visited path; the view
    // must expose it straight out of the frame bytes.
    let msg = Message::Forward {
        img: sample_image(77),
        from_edge: NodeId(2),
        route: ForwardRoute { ttl: 3, visited: vec![NodeId(0), NodeId(3), NodeId(9)] },
    };
    let mut buf = Vec::new();
    encode(&msg, &mut buf);
    let MessageView::Forward { img, from_edge, ttl, visited } = view(&buf).unwrap() else {
        panic!("not a forward view")
    };
    assert_eq!(img, sample_image(77));
    assert_eq!(from_edge, NodeId(2));
    assert_eq!(ttl, 3);
    assert_eq!(visited.len(), 3);
    assert!(!visited.is_empty());
    assert!(visited.contains(NodeId(3)));
    assert!(!visited.contains(NodeId(4)));
    assert_eq!(
        visited.iter().collect::<Vec<NodeId>>(),
        vec![NodeId(0), NodeId(3), NodeId(9)]
    );
    assert_eq!(visited.to_vec(), vec![NodeId(0), NodeId(3), NodeId(9)]);
}

#[test]
fn view_and_decode_reject_exactly_the_same_frames() {
    // Parity on malformed input: for every truncation of every frame
    // (header re-patched so the cut reaches the field readers), the
    // borrowed and owned paths must agree — both succeed with the same
    // message or both fail. Legacy-boundary cuts of routed/relayed frames
    // are *valid* by design, so agreement (not failure) is the assertion.
    let mut buf = Vec::new();
    for msg in all_messages() {
        encode(&msg, &mut buf);
        for cut in 0..buf.len() {
            let mut bad = buf[..cut].to_vec();
            if bad.len() >= 5 {
                let body_len = (bad.len() - 5) as u32;
                bad[1..5].copy_from_slice(&body_len.to_le_bytes());
            }
            match (view(&bad), decode(&bad)) {
                (Err(_), Err(_)) => {}
                (Ok(v), Ok(d)) => assert_eq!(v.to_owned(), d),
                (v, d) => panic!(
                    "paths disagree at cut {cut} of tag 0x{:02x}: view={} decode={}",
                    buf[0],
                    v.is_ok(),
                    d.is_ok()
                ),
            }
        }
        // Corruption parity: unknown tag, oversized header, trailing byte.
        let mut bad = buf.clone();
        bad[0] = 0xEE;
        assert!(view(&bad).is_err() && decode(&bad).is_err());
        let mut bad = buf.clone();
        bad.push(0xFF);
        let padded = (bad.len() - 5) as u32;
        bad[1..5].copy_from_slice(&padded.to_le_bytes());
        assert!(view(&bad).is_err() && decode(&bad).is_err());
    }
    assert!(view(&[]).is_err());
    assert!(view(&[0x03, 0, 0]).is_err());
}

#[test]
fn encoded_len_is_exact_for_every_message() {
    let mut buf = Vec::new();
    for msg in all_messages() {
        let n = encode(&msg, &mut buf);
        assert_eq!(encoded_len(&msg), n, "analytic length must match encode");
    }
}

#[test]
fn batched_frames_decode_individually_through_both_paths() {
    // Batch contract (DESIGN.md §9): a batch is N independent frames
    // back-to-back — no envelope. Peel them with the per-frame header and
    // check view/decode parity on each.
    let msgs = all_messages();
    let mut batch = Vec::new();
    for m in &msgs {
        let n = encode_append(m, &mut batch);
        assert_eq!(n, encoded_len(m));
    }
    let mut off = 0;
    for m in &msgs {
        let len = u32::from_le_bytes(batch[off + 1..off + 5].try_into().unwrap()) as usize;
        let frame = &batch[off..off + 5 + len];
        assert_eq!(&view(frame).unwrap().to_owned(), m);
        assert_eq!(&decode(frame).unwrap(), m);
        off += 5 + len;
    }
    assert_eq!(off, batch.len(), "batch must contain exactly the encoded frames");
}

#[test]
fn every_truncation_is_an_error_not_a_panic() {
    let mut buf = Vec::new();
    for msg in all_messages() {
        encode(&msg, &mut buf);
        let frame = buf.clone();
        // Chop the frame at every possible length, re-patching the header
        // length so the cut exercises the field readers (not just the
        // outer length check). Every strict prefix must be a clean error.
        for cut in 0..frame.len() {
            let mut bad = frame[..cut].to_vec();
            if bad.len() >= 5 {
                let body_len = (bad.len() - 5) as u32;
                bad[1..5].copy_from_slice(&body_len.to_le_bytes());
            }
            assert!(
                decode(&bad).is_err(),
                "truncation to {cut} bytes of tag 0x{:02x} must fail",
                frame[0]
            );
        }
        // Unpatched truncation trips the header/body length check.
        let bad = &frame[..frame.len() - 1];
        assert!(decode(bad).is_err());
    }
}

#[test]
fn corrupted_frames_are_rejected() {
    let mut buf = Vec::new();
    for msg in all_messages() {
        encode(&msg, &mut buf);
        // Unknown tag byte.
        let mut bad = buf.clone();
        bad[0] = 0xEE;
        assert!(decode(&bad).is_err(), "corrupt tag must fail");
        // Header length larger than the body.
        let mut bad = buf.clone();
        let wrong = (buf.len() - 5 + 7) as u32;
        bad[1..5].copy_from_slice(&wrong.to_le_bytes());
        assert!(decode(&bad).is_err(), "oversized header length must fail");
        // Trailing garbage with a consistent header length.
        let mut bad = buf.clone();
        bad.push(0xFF);
        let padded = (bad.len() - 5) as u32;
        bad[1..5].copy_from_slice(&padded.to_le_bytes());
        assert!(decode(&bad).is_err(), "trailing bytes must fail");
    }
    // Sub-header garbage.
    assert!(decode(&[]).is_err());
    assert!(decode(&[0x03]).is_err());
    assert!(decode(&[0x03, 0, 0]).is_err());
}

#[test]
fn legacy_pre_registry_frame_decodes_as_default_app() {
    // Hand-assemble an Image frame in the PRE-registry layout (the flag
    // byte could only be 0 or 1): it must decode cleanly, as the default
    // app with open privacy and priority 0 — and re-encoding it must
    // reproduce the exact same bytes (the default descriptor is omitted
    // on the wire).
    let mut body = Vec::new();
    body.extend_from_slice(&99u64.to_le_bytes()); // task
    body.extend_from_slice(&1u32.to_le_bytes()); // origin
    body.extend_from_slice(&29.0f64.to_le_bytes()); // size_kb
    body.extend_from_slice(&64u32.to_le_bytes()); // side_px
    body.extend_from_slice(&12.5f64.to_le_bytes()); // created_ms
    body.extend_from_slice(&5_000.0f64.to_le_bytes()); // deadline_ms
    body.push(0); // legacy flag byte: no pinned node
    body.extend_from_slice(&99u64.to_le_bytes()); // seq
    let mut frame = vec![0x03];
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(&body);

    let msg = decode(&frame).expect("legacy frame must decode");
    let Message::Image(img) = &msg else { panic!("not an image") };
    assert_eq!(img.task, TaskId(99));
    assert_eq!(img.constraint.app, AppId::DEFAULT);
    assert_eq!(img.constraint.privacy, PrivacyClass::Open);
    assert_eq!(img.constraint.priority, 0);
    assert!(img.constraint.is_default_descriptor());
    // The borrowed path accepts the hand-assembled legacy layout too.
    assert_eq!(view(&frame).expect("legacy frame must view").to_owned(), msg);

    let mut reencoded = Vec::new();
    encode(&msg, &mut reencoded);
    assert_eq!(reencoded, frame, "default-app encoding must be byte-identical to legacy");

    // The pinned variant of the legacy layout decodes too.
    let mut body = Vec::new();
    body.extend_from_slice(&7u64.to_le_bytes());
    body.extend_from_slice(&2u32.to_le_bytes());
    body.extend_from_slice(&87.0f64.to_le_bytes());
    body.extend_from_slice(&128u32.to_le_bytes());
    body.extend_from_slice(&0.0f64.to_le_bytes());
    body.extend_from_slice(&1_000.0f64.to_le_bytes());
    body.push(1); // pinned
    body.extend_from_slice(&3u32.to_le_bytes()); // pin target
    body.extend_from_slice(&7u64.to_le_bytes());
    let mut frame = vec![0x03];
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(&body);
    let Message::Image(img) = decode(&frame).expect("legacy pinned frame") else {
        panic!("not an image")
    };
    assert_eq!(img.constraint.pinned_node, Some(NodeId(3)));
    assert!(img.constraint.is_default_descriptor());
    assert_eq!(view(&frame).unwrap().to_owned(), Message::Image(img));
}

#[test]
fn versioned_routing_sections_roundtrip_and_degrade_to_legacy() {
    // The hierarchical-routing sections (Forward route, EdgeSummary
    // relay) are appended behind version bytes. Three compat rules
    // (DESIGN.md §Wire format): (1) versioned frames roundtrip; (2) a
    // frame truncated exactly at the legacy boundary IS the legacy frame
    // — it decodes with the default route / direct relay; (3) any other
    // truncation inside the section is an error, never a panic.
    let routed = Message::Forward {
        img: sample_image(40),
        from_edge: NodeId(3),
        route: ForwardRoute { ttl: 2, visited: vec![NodeId(0), NodeId(3)] },
    };
    let relayed = Message::EdgeSummary(EdgeSummary {
        edge: NodeId(6),
        busy_containers: 1,
        warm_containers: 4,
        queued_images: 0,
        cpu_load_pct: 12.5,
        device_idle_containers: 3,
        sent_ms: 99.0,
        hops: 2,
        via: NodeId(3),
    });
    for (msg, section_len) in [(&routed, 1 + 1 + 1 + 2 * 4), (&relayed, 1 + 1 + 4)] {
        let mut buf = Vec::new();
        encode(msg, &mut buf);
        assert_eq!(decode(&buf).expect("versioned roundtrip"), *msg);
        let boundary = buf.len() - section_len;
        // Rule 2: the legacy boundary decodes with default routing.
        let mut legacy = buf[..boundary].to_vec();
        let body_len = (legacy.len() - 5) as u32;
        legacy[1..5].copy_from_slice(&body_len.to_le_bytes());
        match decode(&legacy).expect("legacy boundary must decode") {
            Message::Forward { route, .. } => assert_eq!(route, ForwardRoute::default()),
            Message::EdgeSummary(s) => {
                assert_eq!(s.hops, 0);
                assert_eq!(s.via, s.edge);
            }
            other => panic!("unexpected variant {other:?}"),
        }
        // Both decode paths agree at the legacy boundary.
        assert_eq!(
            view(&legacy).expect("legacy boundary must view").to_owned(),
            decode(&legacy).unwrap()
        );
        // Rule 3: every cut strictly inside the section is an error.
        for cut in boundary + 1..buf.len() {
            let mut bad = buf[..cut].to_vec();
            let body_len = (bad.len() - 5) as u32;
            bad[1..5].copy_from_slice(&body_len.to_le_bytes());
            assert!(
                decode(&bad).is_err(),
                "cut at {cut} inside the routing section must fail"
            );
        }
    }
}

#[test]
fn descriptor_corruption_is_rejected() {
    let mut buf = Vec::new();
    encode(&Message::Image(app_image(55, PrivacyClass::CellLocal)), &mut buf);
    // Locate the constraint flags byte: header(5) + task(8) + origin(4) +
    // size(8) + side(4) + created(8) + deadline(8).
    let flags_off = 5 + 8 + 4 + 8 + 4 + 8 + 8;
    assert_eq!(buf[flags_off], 0x03, "pinned + descriptor flags expected");
    // Unknown flag bit must be rejected, not silently decoded.
    let mut bad = buf.clone();
    bad[flags_off] |= 0x80;
    assert!(decode(&bad).is_err());
    // Corrupt privacy tag inside the descriptor (flags, pin u32, app u16).
    let mut bad = buf.clone();
    bad[flags_off + 1 + 4 + 2] = 0x63;
    assert!(decode(&bad).is_err());
}

#[test]
fn cloud_offload_unknown_flags_are_rejected() {
    // Tag 0x0B leads with a version/flags byte (DESIGN.md §9). V1 is
    // all-zero; a frame from a future sender with ANY unknown bit set
    // must be refused by both decode paths, never silently misparsed.
    let msg = Message::CloudOffload { img: sample_image(60), from_edge: NodeId(4) };
    let mut buf = Vec::new();
    encode(&msg, &mut buf);
    assert_eq!(buf[0], 0x0B);
    assert_eq!(buf[5], 0x00, "CLOUD_FLAGS_V1 must encode as all-zero");
    for bit in 0..8 {
        let mut bad = buf.clone();
        bad[5] |= 1 << bit;
        assert!(decode(&bad).is_err(), "unknown cloud flag bit {bit} must be rejected");
        assert!(view(&bad).is_err(), "view must reject cloud flag bit {bit} too");
    }
    // The all-zero frame still roundtrips through both paths.
    assert_eq!(decode(&buf).unwrap(), msg);
    assert_eq!(view(&buf).unwrap().to_owned(), msg);
}

#[test]
fn legacy_tags_encode_unchanged_by_the_cloud_tag() {
    // Adding 0x0B must not shift a single byte of any pre-cloud frame:
    // hand-assemble the classic Ping layout (the newest pre-cloud tag)
    // and pin it against today's encoder.
    let msg = Message::Ping { from: NodeId(7), sent_ms: 1_234.5 };
    let mut expected = vec![0x0Au8];
    expected.extend_from_slice(&12u32.to_le_bytes());
    expected.extend_from_slice(&7u32.to_le_bytes());
    expected.extend_from_slice(&1_234.5f64.to_le_bytes());
    let mut buf = Vec::new();
    encode(&msg, &mut buf);
    assert_eq!(buf, expected, "pre-cloud frames must be byte-identical");
    assert_eq!(decode(&expected).unwrap(), msg);
}

#[test]
fn read_frame_rejects_oversized_bodies() {
    // A hostile header advertising a 65 MiB body must be refused before
    // allocation.
    let mut head = vec![0x03u8];
    head.extend_from_slice(&((65u32) << 20).to_le_bytes());
    let mut cursor = std::io::Cursor::new(head);
    assert!(read_frame(&mut cursor).is_err());
}

#[test]
fn read_frame_roundtrips_through_a_stream() {
    let mut buf = Vec::new();
    for msg in all_messages() {
        encode(&msg, &mut buf);
        let mut cursor = std::io::Cursor::new(buf.clone());
        let frame = read_frame(&mut cursor).expect("read_frame");
        assert_eq!(decode(&frame).expect("decode"), msg);
    }
}

// ---------------------------------------------------------------------
// Property tests: a seeded generator of arbitrary *valid* messages
// (every tag, every optional section drawn at random) roundtrips with
// owned/borrowed parity and exact analytic lengths; arbitrary byte
// mutations, truncations and pure garbage never panic either parser.
// ---------------------------------------------------------------------

use edge_dds::util::SplitMix64;

fn arb_node(r: &mut SplitMix64) -> NodeId {
    NodeId(r.randint(0, 300) as u32)
}

fn arb_constraint(r: &mut SplitMix64) -> Constraint {
    let privacy = match r.randint(0, 2) {
        0 => PrivacyClass::Open,
        1 => PrivacyClass::CellLocal,
        _ => PrivacyClass::DeviceLocal,
    };
    let mut c = Constraint::for_app(
        AppId(r.randint(0, 7) as u16),
        r.range(1.0, 60_000.0),
        privacy,
        r.randint(0, 3) as u8,
    );
    if r.chance(0.5) {
        c.pinned_node = Some(arb_node(r));
    }
    c
}

fn arb_image_meta(r: &mut SplitMix64) -> ImageMeta {
    ImageMeta {
        task: TaskId(r.next_u64() >> 16),
        origin: arb_node(r),
        size_kb: r.range(1.0, 512.0),
        side_px: [64, 128, 256][r.randint(0, 2) as usize],
        created_ms: r.range(0.0, 1e7),
        constraint: arb_constraint(r),
        seq: r.randint(0, 1 << 20),
    }
}

fn arb_user(r: &mut SplitMix64) -> UserRequest {
    UserRequest {
        app_id: r.randint(0, 50) as u32,
        location: (r.range(-100.0, 100.0), r.range(-100.0, 100.0)),
        constraint: arb_constraint(r),
        n_images: r.randint(1, 5_000) as u32,
        interval_ms: r.range(1.0, 1_000.0),
    }
}

fn arb_message(r: &mut SplitMix64) -> Message {
    match r.randint(1, 11) {
        1 => Message::User(arb_user(r)),
        2 => Message::Activate { request: arb_user(r), reply_to: arb_node(r) },
        3 => Message::Image(arb_image_meta(r)),
        4 => Message::Result {
            task: TaskId(r.next_u64() >> 16),
            processed_by: arb_node(r),
            detections: r.randint(0, 40) as u32,
            max_score: r.range(0.0, 8.0) as f32,
            process_ms: r.range(0.1, 4_000.0),
        },
        5 => {
            let battery = r.chance(0.5);
            Message::Profile(ProfileUpdate {
                node: arb_node(r),
                busy_containers: r.randint(0, 64) as u32,
                warm_containers: r.randint(0, 64) as u32,
                queued_images: r.randint(0, 1_000) as u32,
                cpu_load_pct: r.range(0.0, 100.0),
                battery_pct: if battery { Some(r.range(0.0, 100.0)) } else { None },
                sent_ms: r.range(0.0, 1e7),
            })
        }
        6 => Message::Join {
            node: arb_node(r),
            class_tag: r.randint(0, 3) as u8,
            warm_containers: r.randint(0, 16) as u32,
        },
        7 => Message::JoinAck { assigned: arb_node(r) },
        8 => Message::Forward {
            img: arb_image_meta(r),
            from_edge: arb_node(r),
            // Default (legacy) and populated routes both appear.
            route: ForwardRoute {
                ttl: r.randint(0, 6) as u8,
                visited: (0..r.randint(0, 5)).map(|_| arb_node(r)).collect(),
            },
        },
        9 => {
            // Direct (hops 0, via == edge) and relayed forms both appear.
            let edge = arb_node(r);
            let relayed = r.chance(0.5);
            let via = if relayed { arb_node(r) } else { edge };
            Message::EdgeSummary(EdgeSummary {
                edge,
                busy_containers: r.randint(0, 64) as u32,
                warm_containers: r.randint(0, 64) as u32,
                queued_images: r.randint(0, 1_000) as u32,
                cpu_load_pct: r.range(0.0, 100.0),
                device_idle_containers: r.randint(0, 64) as u32,
                sent_ms: r.range(0.0, 1e7),
                hops: if relayed { r.randint(1, 8) as u8 } else { 0 },
                via,
            })
        }
        10 => Message::Ping { from: arb_node(r), sent_ms: r.range(0.0, 1e7) },
        // Any constraint rides the uplink at the wire layer — the privacy
        // clamp is a scheduler invariant, not a codec one.
        _ => Message::CloudOffload { img: arb_image_meta(r), from_edge: arb_node(r) },
    }
}

#[test]
fn property_arbitrary_valid_messages_roundtrip_with_parity() {
    let mut r = SplitMix64::new(0xC17F_EED5);
    let mut buf = Vec::new();
    let mut tags_seen = [false; 12];
    for _ in 0..500 {
        let msg = arb_message(&mut r);
        tags_seen[msg.tag() as usize] = true;
        let n = encode(&msg, &mut buf);
        assert_eq!(n, buf.len());
        assert_eq!(encoded_len(&msg), n, "analytic length must be exact");
        let v = view(&buf).expect("arbitrary valid message must view");
        assert_eq!(v.tag(), msg.tag());
        assert_eq!(v.to_owned(), msg, "borrowed path must reproduce the original");
        assert_eq!(decode(&buf).expect("owned path"), msg);
    }
    assert!(
        tags_seen[1..].iter().all(|&t| t),
        "the generator must reach every wire tag: {tags_seen:?}"
    );
}

#[test]
fn fuzz_mutated_frames_never_panic_and_paths_agree() {
    // Byte flips can mint NaN floats, so successful decodes are compared
    // by re-encoded *bytes* (NaN breaks message equality but not byte
    // equality) — the assertions are: no panic, view/decode agree on
    // accept/reject, and anything accepted re-encodes self-consistently.
    let mut r = SplitMix64::new(0xBAD_C0DE);
    let mut buf = Vec::new();
    for _ in 0..300 {
        let msg = arb_message(&mut r);
        encode(&msg, &mut buf);
        for _ in 0..8 {
            let mut bad = buf.clone();
            for _ in 0..r.randint(1, 3) {
                let i = r.randint(0, bad.len() as u64 - 1) as usize;
                bad[i] ^= (r.next_u64() as u8) | 1;
            }
            match (view(&bad), decode(&bad)) {
                (Err(_), Err(_)) => {}
                (Ok(v), Ok(d)) => {
                    let (mut enc_v, mut enc_d) = (Vec::new(), Vec::new());
                    let n = encode(&v.to_owned(), &mut enc_v);
                    encode(&d, &mut enc_d);
                    assert_eq!(enc_v, enc_d, "paths decoded different messages");
                    assert_eq!(encoded_len(&d), n, "analytic length must hold for mutants");
                }
                (v, d) => panic!(
                    "view/decode disagree on a mutated frame: view={} decode={}",
                    v.is_ok(),
                    d.is_ok()
                ),
            }
        }
        // Random truncation with a re-patched header: reaches the field
        // readers; must return (either way), never panic.
        let cut = r.randint(0, buf.len() as u64) as usize;
        let mut bad = buf[..cut].to_vec();
        if bad.len() >= 5 {
            let body_len = (bad.len() - 5) as u32;
            bad[1..5].copy_from_slice(&body_len.to_le_bytes());
        }
        if let Ok(v) = view(&bad) {
            let _ = v.to_owned();
        }
        // Pure garbage of arbitrary length.
        let junk: Vec<u8> = (0..r.randint(0, 64)).map(|_| r.next_u64() as u8).collect();
        assert_eq!(view(&junk).is_ok(), decode(&junk).is_ok());
    }
}
