//! Integration: the `edge-dds` CLI binary (spawned as a subprocess).

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_edge-dds"))
}

#[test]
fn help_prints_usage() {
    let out = bin().arg("help").output().expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));
    assert!(text.contains("repro"));
}

#[test]
fn sim_runs_and_emits_json() {
    let out = bin()
        .args(["sim", "--policy", "dds", "--images", "20", "--interval", "50", "--deadline", "3000"])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains(r#""name":"dds""#));
    assert!(text.contains(r#""total":20"#));
}

#[test]
fn sweep_covers_paper_policies() {
    let out = bin()
        .args(["sweep", "--images", "10", "--interval", "100", "--deadline", "5000"])
        .output()
        .expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for p in ["aor", "aoe", "eods", "dds"] {
        assert!(text.contains(&format!(r#""name":"{p}""#)), "missing {p}");
    }
}

#[test]
fn repro_table2_matches_paper() {
    let out = bin().args(["repro", "--exp", "table2"]).output().expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Table II"));
    assert!(text.contains("223.0"));
    assert!(text.contains("1163.0"));
}

#[test]
fn repro_fig7_matches_paper() {
    let out = bin().args(["repro", "--exp", "fig7"]).output().expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("374"));
}

#[test]
fn unknown_flags_and_commands_fail_cleanly() {
    let out = bin().arg("frobnicate").output().expect("run");
    assert!(!out.status.success());
    let out = bin().args(["sim", "--images"]).output().expect("run");
    assert!(!out.status.success());
    let out = bin().args(["repro", "--exp", "fig99"]).output().expect("run");
    assert!(!out.status.success());
}

#[test]
fn sim_writes_csv() {
    let dir = std::env::temp_dir().join("edge_dds_cli_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("out.csv");
    let out = bin()
        .args([
            "sim", "--policy", "eods", "--images", "8", "--interval", "100", "--deadline", "5000",
            "--csv",
        ])
        .arg(&path)
        .output()
        .expect("run");
    assert!(out.status.success());
    let csv = std::fs::read_to_string(&path).expect("csv written");
    assert!(csv.starts_with("task,"));
    assert_eq!(csv.lines().count(), 9); // header + 8 tasks
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn config_file_roundtrip() {
    let dir = std::env::temp_dir().join("edge_dds_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        r#"
[run]
seed = 9
policy = "eods"

[workload]
n_images = 15
interval_ms = 100
deadline_ms = 4000

[[device]]
class = "rpi"
warm_containers = 2
camera = true

[[device]]
class = "rpi"
warm_containers = 2
"#,
    )
    .unwrap();
    let out = bin().args(["sim", "--config"]).arg(&path).output().expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains(r#""name":"eods""#));
    assert!(text.contains(r#""total":15"#));
    std::fs::remove_dir_all(&dir).ok();
}
