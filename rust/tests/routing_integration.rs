//! Integration: hierarchical federation routing (DESIGN.md §Hierarchical
//! routing) — multi-hop forwarding on a line topology, loop/TTL safety,
//! weight-aware peer scoring, seeded replay determinism, and the
//! mesh-vs-line legacy equivalence.

use edge_dds::config::SystemConfig;
use edge_dds::core::{NodeId, Placement, PrivacyClass};
use edge_dds::experiments::{fed_config, gossip_config};
use edge_dds::metrics::writer::summary_json;
use edge_dds::metrics::csv_line;
use edge_dds::net::FederationShape;
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::{ArrivalPattern, ScenarioBuilder};
use edge_dds::config::WorkloadConfig;

fn wl(n: u32, interval: f64, deadline: f64) -> WorkloadConfig {
    WorkloadConfig {
        n_images: n,
        interval_ms: interval,
        size_kb: 29.0,
        size_jitter_kb: 0.0,
        deadline_ms: deadline,
        side_px: 64,
        pattern: ArrivalPattern::Uniform,
    }
}

/// The acceptance scenario: a 4-cell line, all load on cell 0, cell 0's
/// edge at 100% background load — capacity beyond the direct neighbor is
/// reachable only through transitive gossip + multi-hop forwarding.
fn four_cell_line(n: u32) -> ScenarioBuilder {
    let mut cfg = gossip_config(4, FederationShape::Line);
    cfg.federation.gossip_period_ms = 25.0;
    // 15 ms (~66 fps) arrivals exceed the first two cells' combined
    // service rate: the far cells are reachable only via multi-hop.
    ScenarioBuilder::new(cfg).workload(wl(n, 15.0, 2_000.0)).edge_load(100.0).seed(3)
}

#[test]
fn line_topology_routes_frames_at_least_two_hops() {
    let r = four_cell_line(300).run();
    assert_eq!(r.summary.total, 300);
    assert_eq!(r.summary.met + r.summary.missed + r.summary.dropped, 300);
    assert!(r.summary.forwarded > 0, "stressed line must forward");
    // Acceptance: at least one frame actually crossed ≥ 2 backhaul hops.
    let multi_hop = r.records.iter().filter(|rec| rec.hops >= 2).count();
    assert!(multi_hop > 0, "no frame routed beyond the direct neighbor");
    assert_eq!(r.summary.forward_hops, r.records.iter().map(|x| x.hops as usize).sum::<usize>());
    assert!(r.summary.forward_hops > r.summary.forwarded);
    // Routing safety: zero loops, zero privacy violations.
    assert_eq!(r.summary.loops_rejected, 0, "loops must be filtered at the sender");
    assert_eq!(r.summary.privacy_violations, 0);
    // Forwarded work actually executed in peer cells and resolved.
    let cross_executed = r
        .records
        .iter()
        .filter(|rec| {
            matches!(rec.placement, Placement::ToPeerEdge(_))
                && rec.executed_on.is_some_and(|n| n.0 >= 3)
        })
        .count();
    assert!(cross_executed > 0, "forwarded frames must run in peer cells");
}

#[test]
fn line_topology_replay_is_byte_identical() {
    // Seeded replay determinism: summaries, records, event counts, and
    // the serialized CSV/JSON artifacts must match byte for byte.
    let a = four_cell_line(200).run();
    let b = four_cell_line(200).run();
    assert_eq!(a.summary, b.summary);
    assert_eq!(a.records, b.records);
    assert_eq!(a.events, b.events);
    assert_eq!(a.virtual_ms, b.virtual_ms);
    assert_eq!(summary_json("line", &a.summary), summary_json("line", &b.summary));
    let ca: Vec<String> = a.records.iter().map(csv_line).collect();
    let cb: Vec<String> = b.records.iter().map(csv_line).collect();
    assert_eq!(ca, cb);
    // The snapshot counters rode along deterministically.
    assert!(a.summary.snapshot_rebuilds > 0);
    assert_eq!(a.summary.snapshot_rebuilds, b.summary.snapshot_rebuilds);
    assert_eq!(a.summary.snapshot_reuses, b.summary.snapshot_reuses);
}

#[test]
fn mesh_single_hop_reproduces_classic_federation_counters() {
    // A mesh with the default hop budget of 1 must behave like the
    // classic federation: hops == forwarded, no loops, no expiries beyond
    // what saturation forces, and forward targets are all edges.
    let r = ScenarioBuilder::new(fed_config(2))
        .workload(wl(300, 30.0, 2_000.0))
        .edge_load(100.0)
        .seed(3)
        .run();
    assert!(r.summary.forwarded > 0);
    assert_eq!(r.summary.forward_hops, r.summary.forwarded);
    assert_eq!(r.summary.loops_rejected, 0);
    for rec in &r.records {
        assert!(rec.hops <= 1, "mesh budget 1 must never relay");
        if let Placement::ToPeerEdge(peer) = rec.placement {
            assert_eq!(peer, NodeId(3));
        }
    }
}

#[test]
fn cell_local_frames_never_route_even_on_a_saturated_line() {
    // Privacy clamps hold on every hop: declare the workload's app
    // cell_local and stress the line — nothing may cross the backhaul.
    let mut cfg = gossip_config(4, FederationShape::Line);
    cfg.federation.gossip_period_ms = 25.0;
    cfg.apps.push(edge_dds::config::AppSpec {
        name: "bound".to_string(),
        deadline_ms: 2_000.0,
        privacy: PrivacyClass::CellLocal,
        priority: 0,
        n_images: 200,
        interval_ms: 30.0,
        size_kb: 29.0,
        side_px: 64,
        pattern: ArrivalPattern::Uniform,
        weight: None,
        admit_rate_per_s: None,
    });
    let r = ScenarioBuilder::new(cfg).edge_load(100.0).seed(3).run();
    assert_eq!(r.summary.total, 200);
    assert_eq!(r.summary.forwarded, 0, "cell-local traffic must not federate");
    assert_eq!(r.summary.forward_hops, 0);
    assert_eq!(r.summary.privacy_violations, 0);
}

#[test]
fn legacy_configs_remain_loop_and_hop_free() {
    // A single-cell config must keep every routing counter at zero and
    // serialize without the routing keys (legacy JSON byte-compat).
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Dds;
    let r = ScenarioBuilder::new(cfg).workload(wl(100, 50.0, 2_000.0)).seed(21).run();
    assert_eq!(r.summary.forward_hops, 0);
    assert_eq!(r.summary.loops_rejected, 0);
    assert_eq!(r.summary.ttl_expired, 0);
    let js = summary_json("legacy", &r.summary);
    assert!(!js.contains("forward_hops"));
    assert!(!js.contains("loops_rejected"));
}
