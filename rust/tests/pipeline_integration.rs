//! Integration: the staged scheduling pipeline (DESIGN.md §3) end to end
//! through the discrete-event driver — admission rejects are counted,
//! overload sheds carry a distinct reason, DRR weights shift dispatch
//! share, and legacy configs (no `[admission]`, no `weight` keys) are
//! untouched by the pipeline's presence.

use edge_dds::config::{AdmissionConfig, AppSpec, SystemConfig};
use edge_dds::container::QueueDiscipline;
use edge_dds::core::{AppId, PrivacyClass};
use edge_dds::metrics::{csv_line, writer::summary_json};
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::{ArrivalPattern, ScenarioBuilder};

fn app(name: &str, priority: u8, deadline_ms: f64, n: u32, interval: f64) -> AppSpec {
    AppSpec {
        name: name.into(),
        deadline_ms,
        privacy: PrivacyClass::Open,
        priority,
        n_images: n,
        interval_ms: interval,
        size_kb: 29.0,
        side_px: 64,
        pattern: ArrivalPattern::Uniform,
        weight: None,
        admit_rate_per_s: None,
    }
}

#[test]
fn admission_rejects_are_counted_not_silently_dropped() {
    // AOE floods the edge at 50 fps; a 5/s token bucket admits only a
    // handful. Every reject must be accounted: distinct verdict in the
    // CSV, `rejected` counter in the summary, accounting identity intact.
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Aoe;
    cfg.workload.n_images = 60;
    cfg.workload.interval_ms = 20.0;
    cfg.workload.deadline_ms = 5_000.0;
    cfg.admission = Some(AdmissionConfig {
        rate_per_s: 5.0,
        burst: 2.0,
        queue_ceiling: 1_000,
        deadline_shed: false,
        device_intake: false,
    });
    let r = ScenarioBuilder::new(cfg).seed(7).run();
    assert_eq!(r.summary.total, 60);
    assert_eq!(r.summary.met + r.summary.missed + r.summary.dropped, 60);
    assert!(r.summary.rejected > 0, "the token bucket must reject under a 10x flood");
    assert!(r.summary.rejected <= r.summary.dropped, "rejects are a subset of drops");
    assert!(r.summary.met > 0, "admitted frames still complete");
    let rejected_lines =
        r.records.iter().filter(|rec| csv_line(rec).ends_with(",rejected")).count();
    assert_eq!(rejected_lines, r.summary.rejected);
    let js = summary_json("admitted", &r.summary);
    assert!(js.contains(&format!(r#""rejected":{}"#, r.summary.rejected)));
}

#[test]
fn overload_shed_records_distinct_reason() {
    // Deadline shed on, rate unlimited: once the pool saturates, queued
    // best-effort frames whose predicted completion exceeds their 600 ms
    // deadline are shed at enqueue with their own verdict spelling.
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Aoe;
    cfg.workload.n_images = 40;
    cfg.workload.interval_ms = 20.0;
    cfg.workload.deadline_ms = 600.0;
    cfg.admission = Some(AdmissionConfig {
        rate_per_s: f64::INFINITY,
        burst: 8.0,
        queue_ceiling: 1_000,
        deadline_shed: true,
        device_intake: false,
    });
    let r = ScenarioBuilder::new(cfg).seed(7).run();
    assert_eq!(r.summary.total, 40);
    assert_eq!(r.summary.met + r.summary.missed + r.summary.dropped, 40);
    assert!(r.summary.shed > 0, "hopeless best-effort frames must be shed at enqueue");
    assert_eq!(r.summary.rejected, 0, "no rate/ceiling rejects configured");
    let shed_lines = r.records.iter().filter(|rec| csv_line(rec).ends_with(",shed")).count();
    assert_eq!(shed_lines, r.summary.shed);
    // Shed frames never executed anywhere.
    for rec in r.records.iter().filter(|rec| csv_line(rec).ends_with(",shed")) {
        assert!(rec.executed_on.is_none());
        assert!(rec.started_ms.is_none());
    }
}

#[test]
fn drr_weights_shift_dispatch_share_under_saturation() {
    // Two equal-priority tenants flooding one cell; weights 2:1. The
    // heavier tenant must complete more frames within the shared
    // deadline than the lighter one (strict priority would be a
    // tie-breaker-ordered free-for-all instead).
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Aoe;
    let mut heavy = app("heavy", 0, 2_500.0, 60, 50.0);
    heavy.weight = Some(2);
    let mut light = app("light", 0, 2_500.0, 60, 50.0);
    light.weight = Some(1);
    cfg.apps = vec![heavy, light];
    assert_eq!(
        cfg.queue_discipline(),
        QueueDiscipline::WeightedFair { weights: vec![2, 1] }
    );
    let r = ScenarioBuilder::new(cfg).seed(7).run();
    assert_eq!(r.summary.total, 120);
    let met = |i: u16| r.summary.app(AppId(i)).map_or(0, |a| a.met);
    assert!(
        met(0) > met(1),
        "weight-2 app must complete more in-deadline frames: {} vs {}",
        met(0),
        met(1)
    );
    // Both tenants make progress — DRR never starves the lighter one.
    assert!(met(1) > 0);
}

#[test]
fn legacy_configs_replay_identically_with_pipeline_defaults() {
    // No [admission], no weight keys: the pipeline stages are structural
    // no-ops. Seeded replay must be byte-identical (CSV and JSON), the
    // summary must carry no admission counters, and the resolved stage
    // parameters must be the inert defaults. (The same invariant that
    // makes the refactor a pure restructuring for PR-3 configs.)
    let cfg = edge_dds::experiments::slo_config(2, 24);
    assert_eq!(cfg.queue_discipline(), QueueDiscipline::PriorityEdf);
    assert!(cfg.admission_params().is_none());
    let run = || {
        let mut c = cfg.clone();
        c.policy = PolicyKind::Dds;
        ScenarioBuilder::new(c).seed(13).run()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.summary, b.summary);
    let csv_a: Vec<String> = a.records.iter().map(csv_line).collect();
    let csv_b: Vec<String> = b.records.iter().map(csv_line).collect();
    assert_eq!(csv_a, csv_b);
    assert_eq!(
        summary_json("replay", &a.summary),
        summary_json("replay", &b.summary)
    );
    assert_eq!((a.summary.rejected, a.summary.shed), (0, 0));
    let js = summary_json("replay", &a.summary);
    // The admission keys must be absent for legacy configs. (Quoted form:
    // the routing counter `"loops_rejected"` is a different, gated key.)
    assert!(!js.contains(r#""rejected""#), "legacy JSON schema must be unchanged");
    assert!(!js.contains(r#""shed""#));
    // No synthetic drop reasons on any legacy record.
    assert!(a.records.iter().all(|rec| {
        let line = csv_line(rec);
        !line.ends_with(",rejected") && !line.ends_with(",shed")
    }));
}

#[test]
fn admission_applies_per_app_overrides_end_to_end() {
    // Strict tenant un-throttled, best-effort tenant rate-limited: only
    // the best-effort app loses frames to admission.
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Aoe;
    let strict = app("strict", 2, 5_000.0, 30, 100.0);
    let mut be = app("besteffort", 0, 5_000.0, 120, 25.0);
    be.admit_rate_per_s = Some(3.0);
    cfg.apps = vec![strict, be];
    cfg.admission = Some(AdmissionConfig {
        rate_per_s: f64::INFINITY,
        burst: 2.0,
        queue_ceiling: 1_000,
        deadline_shed: false,
        device_intake: false,
    });
    let r = ScenarioBuilder::new(cfg).seed(7).run();
    assert_eq!(r.summary.total, 150);
    let strict_row = r.summary.app(AppId(0)).unwrap();
    let be_row = r.summary.app(AppId(1)).unwrap();
    assert_eq!(strict_row.dropped, 0, "unlimited-rate tenant must never be rejected");
    assert!(be_row.dropped > 0, "rate-limited tenant must see rejects");
    assert_eq!(r.summary.rejected, be_row.dropped);
}

#[test]
fn device_intake_admission_fires_and_replays_deterministically() {
    // `device_intake = true` pushes the same token bucket to where frames
    // are born (PR-7 satellite): under a 50 fps flood with a 5/s bucket
    // most frames are refused at the device before crossing the uplink.
    // The counters are identical in kind to edge-side rejects, and seeded
    // replay stays byte-identical with the device bucket in play.
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Aoe;
    cfg.workload.n_images = 60;
    cfg.workload.interval_ms = 20.0;
    cfg.workload.deadline_ms = 5_000.0;
    cfg.admission = Some(AdmissionConfig {
        rate_per_s: 5.0,
        burst: 2.0,
        queue_ceiling: 1_000,
        deadline_shed: false,
        device_intake: true,
    });
    assert_eq!(cfg.device_admission_params(), cfg.admission_params());
    let run = || ScenarioBuilder::new(cfg.clone()).seed(7).run();
    let (a, b) = (run(), run());
    assert_eq!(a.summary.total, 60);
    assert_eq!(a.summary.met + a.summary.missed + a.summary.dropped, 60);
    assert!(a.summary.rejected > 0, "the device bucket must reject under a 10x flood");
    assert!(a.summary.met > 0, "admitted frames still complete");
    let rejected_lines =
        a.records.iter().filter(|rec| csv_line(rec).ends_with(",rejected")).count();
    assert_eq!(rejected_lines, a.summary.rejected);
    assert_eq!(a.summary, b.summary);
    let csv_a: Vec<String> = a.records.iter().map(csv_line).collect();
    let csv_b: Vec<String> = b.records.iter().map(csv_line).collect();
    assert_eq!(csv_a, csv_b);
}
