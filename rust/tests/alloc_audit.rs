//! Allocation audit (DESIGN.md §Engine internals): a counting global
//! allocator measures the steady-state decision path. The engine's event
//! loop reuses its action scratch, the heartbeat sweep reuses its
//! dead/requeue buffers, and gossip ticks fill engine-held batches — so
//! the *marginal* allocation cost of one extra frame must stay small and
//! flat. The test measures two otherwise-identical runs of different
//! sizes and bounds the per-frame difference: an O(candidates) Vec (or
//! worse) sneaking back into the per-frame path trips it, while amortized
//! slab/queue growth (doubling reallocs, O(log n) events) does not.
//!
//! This file holds exactly one #[test]: the counter is process-global, and
//! a second test running on a sibling thread would pollute the window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use edge_dds::experiments::city_config;
use edge_dds::net::FederationShape;
use edge_dds::sim::ScenarioBuilder;

/// System allocator wrapped with an on/off event counter. Counts
/// allocation *events* (alloc + realloc), not bytes: the audit cares about
/// per-frame churn, and a reused buffer that grows once is the success
/// case, not a failure.
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);
static COUNTING: AtomicBool = AtomicBool::new(false);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Build a mesh city outside the counting window (construction is allowed
/// to allocate freely), then count allocation events across `run()` alone.
/// Returns (events, frames recorded).
fn counted_run(images_per_camera: u32) -> (u64, u64) {
    let cfg = city_config(4, FederationShape::Mesh, images_per_camera);
    let mut eng = ScenarioBuilder::new(cfg).seed(0xA110C).build();
    ALLOC_EVENTS.store(0, Ordering::Relaxed);
    COUNTING.store(true, Ordering::SeqCst);
    eng.run();
    COUNTING.store(false, Ordering::SeqCst);
    let events = ALLOC_EVENTS.load(Ordering::Relaxed);
    (events, eng.recorder.len() as u64)
}

#[test]
fn marginal_allocations_per_frame_stay_bounded() {
    // Warm-up run swallows one-time lazy init (logger state, TLS, runtime
    // tables) so neither measured window pays for it asymmetrically.
    let _ = counted_run(10);

    let (small_events, small_frames) = counted_run(20);
    let (large_events, large_frames) = counted_run(120);
    assert!(
        large_frames > small_frames,
        "size knob must change the workload ({small_frames} vs {large_frames})"
    );

    // Marginal cost of one extra frame, averaged over the size delta. The
    // absolute count is noisy (hash seeds, growth schedules); the slope is
    // the contract. The bound is a generous envelope over the legitimate
    // per-frame work — record-slab push, inflight map insert, a handful of
    // sim deliveries — sized to catch a per-candidate or per-event buffer
    // regression, which costs tens of extra events per frame.
    let marginal =
        (large_events.saturating_sub(small_events)) as f64 / (large_frames - small_frames) as f64;
    assert!(
        marginal < 48.0,
        "per-frame allocation churn regressed: {marginal:.1} events/frame \
         ({small_events} events @ {small_frames} frames → {large_events} @ {large_frames})"
    );
}
