//! Engine-twin replay pin (DESIGN.md §Engine internals): the bucketed
//! calendar queue must be **byte-identical** to the classic binary heap —
//! not "statistically equivalent", the same replay. Each twin runs one
//! scenario under [`QueueKind::Classic`] and [`QueueKind::Wheel`] and
//! compares the *full rendered output* (every CSV record line plus the
//! summary JSON plus the event/virtual-clock counters), so any divergence
//! in pop order — however it launders itself through placement, queueing
//! or retransmit timing — fails the diff, byte for byte.
//!
//! Coverage follows the repro surface: federation (cross-cell backhaul),
//! churn (failure detectors + requeue), SLO (3-tenant app registry), and
//! city scale (16 cells, hierarchical gossip), plus the coalesced
//! lazy-stream path and the `set_max_events` truncation guard.

use edge_dds::config::{SystemConfig, WorkloadConfig};
use edge_dds::experiments::{
    apply_scenario, churn_config, city_config, fed_config, slo_config, tier_config,
    ChurnScenario,
};
use edge_dds::metrics::{csv_line, writer::summary_json};
use edge_dds::net::FederationShape;
use edge_dds::sim::{ArrivalPattern, QueueKind, RunReport, ScenarioBuilder};

fn wl(n_images: u32, interval_ms: f64, deadline_ms: f64) -> WorkloadConfig {
    WorkloadConfig {
        n_images,
        interval_ms,
        size_kb: 29.0,
        size_jitter_kb: 0.0,
        deadline_ms,
        side_px: 64,
        pattern: ArrivalPattern::Uniform,
    }
}

/// Render everything observable about a run into one string: the summary
/// JSON, every per-task CSV line in record order, and the engine's
/// event/clock counters. Byte equality of this string is the twin
/// contract.
fn full_render(r: &RunReport) -> String {
    let mut out = summary_json("twin", &r.summary);
    out.push('\n');
    for rec in &r.records {
        out.push_str(&csv_line(rec));
        out.push('\n');
    }
    out.push_str(&format!("events={} virtual_ms={}\n", r.events, r.virtual_ms));
    out
}

/// Run `builder` under both queue kinds and assert byte-identical output.
fn assert_twin(label: &str, builder: impl Fn() -> ScenarioBuilder) {
    let classic = builder().queue(QueueKind::Classic).run();
    let wheel = builder().queue(QueueKind::Wheel).run();
    let (a, b) = (full_render(&classic), full_render(&wheel));
    assert!(
        a == b,
        "{label}: classic heap and calendar wheel diverged.\n\
         First difference at byte {}.\nclassic:\n{}\nwheel:\n{}",
        a.bytes().zip(b.bytes()).position(|(x, y)| x != y).unwrap_or(a.len().min(b.len())),
        a,
        b
    );
    // The twin must also actually do something — a trivially empty run
    // would pass the diff vacuously.
    assert!(classic.summary.total > 0, "{label}: no frames ran");
    assert!(classic.events > 0, "{label}: no events processed");
}

#[test]
fn federation_twin_is_byte_identical() {
    assert_twin("fed 2-cell", || {
        ScenarioBuilder::new(fed_config(2)).workload(wl(60, 50.0, 3_000.0)).seed(11)
    });
}

#[test]
fn churn_twin_is_byte_identical() {
    // Failure detectors, requeue-off-the-dead and heartbeat timers all in
    // the event stream — the densest same-timestamp traffic we have.
    assert_twin("device churn", || {
        let mut cfg = churn_config(2);
        cfg.workload = wl(80, 100.0, 2_500.0);
        let span = cfg.span_ms();
        apply_scenario(&mut cfg, ChurnScenario::DeviceChurn, span);
        ScenarioBuilder::new(cfg).seed(5)
    });
}

#[test]
fn slo_twin_is_byte_identical() {
    // Three tenants with distinct privacy classes and priorities: the
    // per-app queues exercise tie-breaks between equal-deadline frames.
    assert_twin("slo 3-app", || ScenarioBuilder::new(slo_config(2, 24)).seed(9));
}

#[test]
fn city_twin_is_byte_identical() {
    // 4 cells, mesh backhaul, per-cell cameras, hierarchical gossip off —
    // the widest topology in the tier-1 budget.
    assert_twin("city mesh-4", || {
        ScenarioBuilder::new(city_config(4, FederationShape::Mesh, 12)).seed(3)
    });
}

#[test]
fn tier_twin_is_byte_identical() {
    // Cloud uplink events in flight (DESIGN.md §4e): a saturated lone
    // cell spills its open tenant over the WAN uplink, so CloudOffload
    // sends, synthetic cloud-container completions and Result relays all
    // ride the pending-event structure under test.
    assert_twin("tier cloud 1-cell 4x", || {
        ScenarioBuilder::new(tier_config(1, 4, Some(20.0), 40)).seed(7)
    });
    // The twin is only meaningful if the uplink actually carried frames.
    let r = ScenarioBuilder::new(tier_config(1, 4, Some(20.0), 40)).seed(7).run();
    assert!(r.summary.cloud_tasks > 0, "twin scenario must put uplink events in flight");
}

#[test]
fn coalesced_stream_twin_is_byte_identical() {
    // The lazy one-arrival-in-flight path is its own replay universe
    // (relative to pre-scheduled arrivals) but must be the SAME universe
    // under either pending-event structure.
    assert_twin("coalesced streams", || {
        let mut cfg = SystemConfig::default();
        cfg.workload = wl(50, 50.0, 2_000.0);
        ScenarioBuilder::new(cfg).seed(7).coalesce(1)
    });
}

#[test]
fn max_events_truncation_is_byte_identical() {
    // The abort guard breaks the run loop mid-flight; both queues must
    // truncate at the same event with the same unresolved-task accounting.
    let builder = || {
        ScenarioBuilder::new(fed_config(2))
            .workload(wl(60, 50.0, 3_000.0))
            .seed(11)
            .max_events(500)
    };
    let classic = builder().queue(QueueKind::Classic).run();
    let wheel = builder().queue(QueueKind::Wheel).run();
    assert_eq!(full_render(&classic), full_render(&wheel));
    // The cap genuinely bit: the run stopped at the budget and stranded
    // work summarizes as dropped, exactly like a horizon break.
    // (The loop breaks on the first event past the budget, so the
    // processed count is cap + 1 — same contract as the engine's own
    // `max_events` unit test.)
    assert_eq!(classic.events, 501, "breaks on the first event past the budget");
    assert!(classic.summary.dropped > 0, "truncated run must strand frames");
}
