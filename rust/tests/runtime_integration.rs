//! Integration: the PJRT runtime against real AOT artifacts.
//!
//! These tests need the `pjrt` feature (the whole file compiles away
//! without it — the default build ships the stub backend) and `make
//! artifacts` to have run; they are skipped (with a visible message) if
//! `artifacts/` is absent so `cargo test` stays green on a fresh checkout.
#![cfg(feature = "pjrt")]

use edge_dds::runtime::{ModelRuntime, RuntimeService};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("face_64.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn loads_and_compiles_all_variants() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load(&dir).expect("load artifacts");
    assert!(rt.variant_count() >= 3, "expected 64/128/256 variants");
    assert_eq!(rt.sides(), vec![64, 128, 256]);
}

#[test]
fn detect_shapes_and_determinism() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load(&dir).expect("load artifacts");
    let img = ModelRuntime::synth_image(64, 7);
    let a = rt.detect(64, &img).expect("detect");
    let b = rt.detect(64, &img).expect("detect");
    assert_eq!(a, b, "PJRT execution must be deterministic");
    assert_eq!(a.counts.len(), 4);
    assert_eq!(a.hist.len(), 16);
    // 64 px → 2 pyramid levels; unused level counts must be zero.
    assert_eq!(a.counts[2], 0.0);
    assert_eq!(a.counts[3], 0.0);
    // Histogram total equals total survivors (model invariant).
    let hist_sum: f32 = a.hist.iter().sum();
    assert!((hist_sum - a.total()).abs() < 1e-3, "hist {hist_sum} vs counts {}", a.total());
}

#[test]
fn detect_rejects_bad_input() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load(&dir).expect("load artifacts");
    assert!(rt.detect(64, &[0.0; 7]).is_err(), "wrong pixel count");
    assert!(rt.detect(96, &ModelRuntime::synth_image(96, 0)).is_err(), "unknown side");
}

#[test]
fn pick_side_prefers_fitting_variant() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load(&dir).expect("load artifacts");
    assert_eq!(rt.pick_side(64), 64);
    assert_eq!(rt.pick_side(100), 128);
    assert_eq!(rt.pick_side(999), 256);
    assert_eq!(rt.pick_side(1), 64);
}

#[test]
fn bigger_images_do_more_work() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load(&dir).expect("load artifacts");
    // Time 3 runs each; the 256 variant must be slower than the 64 one
    // (Table II's size→runtime effect on the real compute path).
    let time = |side: u32| {
        let img = ModelRuntime::synth_image(side, 1);
        (0..3)
            .map(|_| rt.detect_timed(side, &img).expect("detect").1)
            .fold(f64::INFINITY, f64::min)
    };
    let t64 = time(64);
    let t256 = time(256);
    assert!(
        t256 > 2.0 * t64,
        "256 px ({t256:.1} ms) should be well above 64 px ({t64:.1} ms)"
    );
}

#[test]
fn runtime_service_concurrent_clients() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = RuntimeService::spawn(&dir).expect("spawn service");
    let mut handles = Vec::new();
    for i in 0..4u64 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let (det, ms) = svc.detect_synth(64, i).expect("detect");
            assert!(ms > 0.0);
            det
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // Different seeds ⇒ (almost surely) different detections; same seed
    // re-run matches.
    let (again, _ms) = svc.detect_synth(64, 0).expect("detect");
    assert_eq!(results[0], again);
}
