//! Tier integration wall (DESIGN.md §4e): the elastic cloud tier must be
//! *deterministic*, *structurally inert* when unconfigured, and *privacy
//! tight* under the worst conditions we can synthesize.
//!
//! Three groups:
//!
//! 1. **Seeded replay** — a cloud-engaged run and the rendered `--exp
//!    tier` sweep output are byte-identical across replays (summary JSON
//!    plus every CSV record line plus the engine counters).
//! 2. **Structural inertness** — legacy configs without `[cloud]` parse
//!    to `cloud: None` and serialize without a single cloud key; a
//!    cloud-blind policy produces byte-identical output whether
//!    `[cloud]` is configured or not (the cloud node joins nothing,
//!    gossips nothing and times nothing — it only exists for frames
//!    deliberately placed on it).
//! 3. **Privacy wall** — under randomized device churn *and* 4× overload,
//!    for every policy the repo ships, no `cell_local`/`device_local`
//!    frame is ever placed on or executed at the cloud node and
//!    `privacy_violations` stays 0. Churn matters here: the requeue path
//!    re-places frames outside the normal pipeline and must clamp too.

use edge_dds::config::{RandomChurnConfig, SystemConfig};
use edge_dds::core::{Placement, PrivacyClass};
use edge_dds::experiments::{render_tier, tier_config, tier_run};
use edge_dds::metrics::{csv_line, writer::summary_json};
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::{RunReport, ScenarioBuilder};

/// Every policy the scheduler knows — the paper's four plus the
/// ablations and extensions. The privacy wall must hold for all of
/// them, not just the cloud-aware ones.
const ALL_POLICIES: [PolicyKind; 8] = [
    PolicyKind::Aor,
    PolicyKind::Aoe,
    PolicyKind::Eods,
    PolicyKind::Dds,
    PolicyKind::DdsNoAvail,
    PolicyKind::DdsEnergy,
    PolicyKind::RoundRobin,
    PolicyKind::Random,
];

/// Render everything observable about a run into one string: the summary
/// JSON, every per-task CSV line in record order, and the engine's
/// event/clock counters. Byte equality of this string is the replay and
/// inertness contract (same shape as the engine-twin pin).
fn full_render(r: &RunReport) -> String {
    let mut out = summary_json("tier", &r.summary);
    out.push('\n');
    for rec in &r.records {
        out.push_str(&csv_line(rec));
        out.push('\n');
    }
    out.push_str(&format!("events={} virtual_ms={}\n", r.events, r.virtual_ms));
    out
}

// ---------------------------------------------------------------- replay

#[test]
fn seeded_cloud_run_replays_byte_identically() {
    let mk = || ScenarioBuilder::new(tier_config(1, 4, Some(20.0), 40)).seed(7).run();
    let (a, b) = (mk(), mk());
    // The pin is only meaningful if the uplink actually carried frames.
    assert!(a.summary.cloud_tasks > 0, "scenario must engage the cloud");
    assert!(a.summary.total > 0 && a.events > 0, "scenario must do work");
    assert_eq!(full_render(&a), full_render(&b), "cloud replay diverged");
}

#[test]
fn exp_tier_rendered_sweep_replays_byte_identically() {
    // A slice of the `repro --exp tier` sweep, rendered twice from
    // independent runs: the report the CLI prints — cost columns,
    // per-tenant met fractions, privacy line — must be byte-stable.
    let mk = || {
        vec![
            tier_run(1, 2, PolicyKind::Dds, None, 11, 20),
            tier_run(1, 2, PolicyKind::Dds, Some(20.0), 11, 20),
            tier_run(1, 2, PolicyKind::Aoe, Some(80.0), 11, 20),
        ]
    };
    let (a, b) = (render_tier(&mk()), render_tier(&mk()));
    assert!(a.contains("cloud_tasks") && a.contains("cloud_s"), "cost columns missing");
    assert!(a.contains("Tier privacy violations (all runs): 0"), "privacy line missing");
    assert_eq!(a, b, "rendered tier sweep diverged across replays");
}

// ------------------------------------------------------------- inertness

#[test]
fn legacy_config_without_cloud_parses_and_serializes_cloud_free() {
    // A pre-tier config file: no `[cloud]` table anywhere.
    let text = r#"
[run]
seed = 3
policy = "dds"

[workload]
n_images = 40
interval_ms = 50
deadline_ms = 2000
"#;
    let cfg = SystemConfig::from_toml(text).unwrap();
    assert!(cfg.cloud.is_none(), "legacy config must parse to cloud: None");
    let r = ScenarioBuilder::new(cfg).run();
    assert!(r.summary.total > 0);
    assert_eq!(r.summary.cloud_tasks, 0);
    assert_eq!(r.summary.cloud_seconds, 0.0);
    // The gated serializers leak nothing: no cloud key in the summary
    // JSON, no cloud placement in any record line.
    let js = summary_json("legacy", &r.summary);
    assert!(!js.contains("cloud"), "cloud-blind summary JSON must carry no cloud keys");
    for rec in &r.records {
        assert!(!csv_line(rec).contains("cloud"), "cloud-blind CSV must carry no cloud spellings");
    }
}

#[test]
fn cloud_config_knobs_parse() {
    let text = r#"
[run]
policy = "dds"

[cloud]
uplink_latency_ms = 120
uplink_bandwidth_mbps = 2500
warm_containers = 64
"#;
    let cfg = SystemConfig::from_toml(text).unwrap();
    let cl = cfg.cloud.expect("[cloud] table must enable the tier");
    assert_eq!(cl.uplink.latency_ms, 120.0);
    assert_eq!(cl.uplink.bandwidth_mbps, 2_500.0);
    assert_eq!(cl.warm_containers, 64);
}

#[test]
fn cloud_blind_policies_are_byte_identical_with_and_without_cloud() {
    // Structural inertness, the strong form: for a policy that never
    // consults the cloud candidate, configuring `[cloud]` changes the
    // topology (one more node, uplinks to every edge) but must not
    // change a single byte of output — the cloud node emits no events
    // of its own. This is the guarantee that keeps every paper
    // comparison valid after the tier landed.
    for policy in [PolicyKind::Aor, PolicyKind::Aoe, PolicyKind::Eods, PolicyKind::RoundRobin] {
        let run = |uplink: Option<f64>| {
            let mut cfg = tier_config(2, 2, uplink, 30);
            cfg.policy = policy;
            ScenarioBuilder::new(cfg).seed(5).run()
        };
        let (with, without) = (run(Some(80.0)), run(None));
        assert_eq!(with.summary.cloud_tasks, 0, "{} must stay cloud-blind", policy.as_str());
        assert_eq!(
            full_render(&with),
            full_render(&without),
            "{}: [cloud] perturbed a cloud-blind run",
            policy.as_str()
        );
    }
}

// ---------------------------------------------------------- privacy wall

#[test]
fn privacy_wall_holds_under_churn_and_overload_for_every_policy() {
    // Two tenants (open + cell_local) at 4× the sustainable rate, with
    // randomized device churn dense enough to force requeues mid-run,
    // and a metro-latency cloud behind every edge. Swept over 1 cell
    // (no peers — maximum cloud pressure) and 2 cells (ToPeerEdge in
    // play — the scoped tenant crosses cells legally while the wall
    // holds). For every policy: zero violations, and not one scoped
    // frame placed on or executed at the cloud node.
    let mut total_cloud_tasks = 0_usize;
    let mut total_requeues = 0_u32;
    for cells in [1_usize, 2] {
        for (i, &policy) in ALL_POLICIES.iter().enumerate() {
            let mut cfg = tier_config(cells, 4, Some(20.0), 60);
            cfg.policy = policy;
            cfg.churn.random = Some(RandomChurnConfig {
                device_mtbf_ms: 600.0,
                device_mttr_ms: 200.0,
            });
            let builder = ScenarioBuilder::new(cfg).seed(31 + i as u64);
            let cloud_id = builder.topology().cloud().expect("[cloud] must add a node");
            let r = builder.run();
            let label = format!("{} @ {cells} cell(s)", policy.as_str());
            assert_eq!(r.summary.privacy_violations, 0, "{label}: violations leaked");
            let mut scoped = 0_usize;
            for rec in &r.records {
                if rec.privacy != PrivacyClass::Open {
                    scoped += 1;
                    assert!(
                        !matches!(rec.placement, Placement::ToCloud(_)),
                        "{label}: scoped task {:?} placed on the cloud",
                        rec.task
                    );
                    assert_ne!(
                        rec.executed_on,
                        Some(cloud_id),
                        "{label}: scoped task {:?} executed at the cloud",
                        rec.task
                    );
                }
                total_requeues += rec.requeues;
            }
            assert!(scoped > 0, "{label}: scenario lost its scoped tenant");
            total_cloud_tasks += r.summary.cloud_tasks;
        }
    }
    // Non-vacuity: the sweep genuinely exercised both hazards — frames
    // did cross the uplink (so the wall had something to hold against),
    // and churn did requeue frames (so the requeue re-placement path ran
    // with a cloud candidate available).
    assert!(total_cloud_tasks > 0, "no run engaged the cloud — the wall was never tested");
    assert!(total_requeues > 0, "no run requeued — churn never pressured the clamp");
}
