//! Bench target: L3 hot paths — scheduler decision latency (with and
//! without candidate-snapshot reuse), container-pool operations, predictor
//! evaluation, wire codec (owned decode vs borrowed view), transport
//! batching, and whole-engine event throughput. These are the §Perf
//! numbers in EXPERIMENTS.md.
//!
//! Besides the console report, the run writes a machine-readable summary
//! (decide/dispatch ns/op) to `$BENCH_JSON` (default `BENCH_9.json`) so
//! the perf trajectory is recorded across PRs; CI uploads it as an
//! artifact and `scripts/bench_check` gates the decode-path, queue and
//! record-store numbers against the committed baseline.
//!
//! Run: `cargo bench --bench hotpath`

#[path = "common/mod.rs"]
mod common;

use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap, HashMap};

use common::{bench, black_box, per_op_ns, section, write_bench_json, BenchResult};
use edge_dds::config::WorkloadConfig;
use edge_dds::container::ContainerPool;
use edge_dds::core::message::ProfileUpdate;
use edge_dds::core::wire;
use edge_dds::core::{
    AppId, Constraint, ImageMeta, Message, NodeClass, NodeId, PrivacyClass, TaskId,
};
use edge_dds::net::LinkModel;
use edge_dds::profile::{profile_for, PeerTable, PredictInput, Predictor, ProfileTable};
use edge_dds::scheduler::{
    DeviceCtx, EdgeCtx, EdgePipeline, LocalSnapshot, PolicyKind, PredictorSet,
};
use edge_dds::sim::ArrivalPattern;
use edge_dds::sim::ScenarioBuilder;

fn img(task: u64) -> ImageMeta {
    ImageMeta {
        task: TaskId(task),
        origin: NodeId(1),
        size_kb: 29.0,
        side_px: 64,
        created_ms: 0.0,
        constraint: Constraint::deadline(5_000.0),
        seq: task,
    }
}

fn main() {
    // (result, per-op ns) pairs for the machine-readable summary.
    let mut json: Vec<(BenchResult, Option<f64>)> = Vec::new();

    section("predictor");
    let pred = Predictor::new(profile_for(NodeClass::RaspberryPi));
    let inp = PredictInput {
        size_kb: 87.0,
        link: None,
        busy_containers: 1,
        warm_containers: 2,
        queued_images: 3,
        cpu_load_pct: 25.0,
    };
    const PRED_BATCH: u32 = 10_000;
    let r = bench("predict_total_ms x10k", 3, 30, || {
        for _ in 0..PRED_BATCH {
            black_box(pred.predict_total_ms(black_box(&inp)));
        }
    });
    r.print_throughput(PRED_BATCH as f64, "predictions");
    json.push((r.clone(), Some(per_op_ns(&r, PRED_BATCH as f64))));

    section("device-level DDS decision");
    let mut dds = PolicyKind::Dds.build(1);
    let frame = img(1);
    let ctx = DeviceCtx {
        now_ms: 10.0,
        img: &frame,
        local: LocalSnapshot {
            node: NodeId(1),
            busy_containers: 1,
            warm_containers: 2,
            queued_images: 1,
            cpu_load_pct: 10.0,
            battery_pct: None,
        },
        predictor: &pred,
        edge_suspected: false,
    };
    const DEC_BATCH: u32 = 10_000;
    let r = bench("decide_device x10k", 3, 30, || {
        for _ in 0..DEC_BATCH {
            black_box(dds.decide_device(black_box(&ctx)));
        }
    });
    r.print_throughput(DEC_BATCH as f64, "decisions");
    json.push((r.clone(), Some(per_op_ns(&r, DEC_BATCH as f64))));

    section("constraint-aware edge decision (pipeline snapshot + EDF + privacy)");
    // The edge-level decision against a populated MP table and a
    // gossip-fed peer table, with app descriptors cycling through all
    // three privacy classes. The pipeline builds one candidate snapshot
    // per decision and reuses it verbatim while tables/suspects/instant
    // are unchanged (DESIGN.md §3) — both variants are measured so the
    // BENCH json records the reuse win.
    let mut dds_edge = PolicyKind::Dds.build(1);
    let mut table = ProfileTable::new();
    for n in 2..=5u32 {
        table.register(NodeId(n), NodeClass::RaspberryPi, 2, 0.0);
        table.apply(&ProfileUpdate {
            node: NodeId(n),
            busy_containers: n % 2,
            warm_containers: 2,
            queued_images: 0,
            cpu_load_pct: 10.0 * n as f64,
            battery_pct: None,
            sent_ms: 5.0,
        });
    }
    let mut peers = PeerTable::new();
    peers.apply(&edge_dds::core::message::EdgeSummary {
        edge: NodeId(9),
        busy_containers: 1,
        warm_containers: 4,
        queued_images: 0,
        cpu_load_pct: 0.0,
        device_idle_containers: 2,
        sent_ms: 5.0,
        hops: 0,
        via: NodeId(9),
    });
    let predictors = PredictorSet::new();
    let no_suspects = BTreeSet::new();
    let links: Vec<Option<LinkModel>> = (0..10).map(|_| Some(LinkModel::wifi())).collect();
    let classes = [PrivacyClass::Open, PrivacyClass::CellLocal, PrivacyClass::DeviceLocal];
    let frames: Vec<ImageMeta> = (0..3u64)
        .map(|i| {
            let mut f = img(i);
            f.constraint = Constraint::for_app(
                AppId(i as u16),
                5_000.0,
                classes[i as usize],
                (i % 3) as u8,
            );
            f
        })
        .collect();
    let edge_snapshot = LocalSnapshot {
        node: NodeId(0),
        busy_containers: 4, // saturated: the peer path is live
        warm_containers: 4,
        queued_images: 1,
        cpu_load_pct: 0.0,
        battery_pct: None,
    };
    const EDGE_BATCH: u32 = 10_000;
    let mut pipe = EdgePipeline::new(None);
    // Warm path: same instant, same origin, unmutated tables — the
    // snapshot is built once and reused across the whole batch (the
    // common case inside a same-tick arrival burst).
    let r = bench("decide_edge(privacy mix, snapshot reuse) x10k", 3, 30, || {
        for i in 0..EDGE_BATCH {
            let frame = &frames[(i % 3) as usize];
            let candidates =
                pipe.prepare(&table, &peers, &no_suspects, 0, &links, frame.origin, 10.0, 200.0);
            let ctx = EdgeCtx {
                now_ms: 10.0,
                img: black_box(frame),
                edge: edge_snapshot,
                predictors: &predictors,
                candidates,
                forwarded: false,
                hops_left: 1,
                visited: &[],
                app_weight: 1,
                cloud: None,
            };
            black_box(dds_edge.decide_edge(&ctx));
        }
    });
    r.print_throughput(EDGE_BATCH as f64, "decisions");
    json.push((r.clone(), Some(per_op_ns(&r, EDGE_BATCH as f64))));

    // Cold path: the cache is invalidated before every decision, so each
    // one pays the full table scan + link resolution — the pre-pipeline
    // per-decision cost, measured for the trajectory delta.
    let r = bench("decide_edge(privacy mix, cold snapshot) x10k", 3, 30, || {
        for i in 0..EDGE_BATCH {
            let frame = &frames[(i % 3) as usize];
            pipe.invalidate();
            let candidates =
                pipe.prepare(&table, &peers, &no_suspects, 0, &links, frame.origin, 10.0, 200.0);
            let ctx = EdgeCtx {
                now_ms: 10.0,
                img: black_box(frame),
                edge: edge_snapshot,
                predictors: &predictors,
                candidates,
                forwarded: false,
                hops_left: 1,
                visited: &[],
                app_weight: 1,
                cloud: None,
            };
            black_box(dds_edge.decide_edge(&ctx));
        }
    });
    r.print_throughput(EDGE_BATCH as f64, "decisions");
    json.push((r.clone(), Some(per_op_ns(&r, EDGE_BATCH as f64))));

    // Cloud-tail decision (DESIGN.md §4e): an exhausted edge with no MP
    // or peer candidates, so every decision walks the full fallback tail
    // (device offload → federation → cloud) and prices the WAN uplink.
    // New entry for the trajectory — not in the bench_check gate.
    let cloud_cc = edge_dds::scheduler::CloudCandidate {
        node: NodeId(42),
        uplink: LinkModel::new(40.0, 10_000.0, 0.0),
    };
    let empty_table = ProfileTable::new();
    let empty_peers = PeerTable::new();
    let mut pipe_cloud = EdgePipeline::new(None);
    let open_frame = &frames[0]; // privacy `open`: the only cloud-eligible class
    let r = bench("decide_edge(cloud tail) x10k", 3, 30, || {
        for _ in 0..EDGE_BATCH {
            let candidates = pipe_cloud.prepare(
                &empty_table,
                &empty_peers,
                &no_suspects,
                0,
                &links,
                open_frame.origin,
                10.0,
                200.0,
            );
            let ctx = EdgeCtx {
                now_ms: 10.0,
                img: black_box(open_frame),
                edge: edge_snapshot, // saturated: the cloud tail is live
                predictors: &predictors,
                candidates,
                forwarded: false,
                hops_left: 1,
                visited: &[],
                app_weight: 1,
                cloud: Some(cloud_cc),
            };
            black_box(dds_edge.decide_edge(&ctx));
        }
    });
    r.print_throughput(EDGE_BATCH as f64, "decisions");
    json.push((r.clone(), Some(per_op_ns(&r, EDGE_BATCH as f64))));

    // Incremental snapshot maintenance (DESIGN.md §3): a UP push lands
    // between decisions, so every prepare sees a moved table version.
    // The delta path patches the one changed entry in place; with
    // incremental maintenance off the same miss pays the full table
    // scan + link resolution. `scripts/bench_check` gates the delta
    // number — it must stay under the rebuild.
    let push = |table: &mut ProfileTable, i: u32| {
        let n = 2 + (i % 4);
        table.apply(&ProfileUpdate {
            node: NodeId(n),
            busy_containers: i % 2,
            warm_containers: 2,
            queued_images: i % 3,
            cpu_load_pct: 10.0 * n as f64,
            battery_pct: None,
            sent_ms: 5.0,
        });
    };
    let r = bench("snapshot delta (profile push) x10k", 3, 30, || {
        for i in 0..EDGE_BATCH {
            push(&mut table, i);
            black_box(pipe.prepare(
                &table,
                &peers,
                &no_suspects,
                0,
                &links,
                frames[0].origin,
                10.0,
                200.0,
            ));
        }
    });
    r.print_throughput(EDGE_BATCH as f64, "patches");
    json.push((r.clone(), Some(per_op_ns(&r, EDGE_BATCH as f64))));
    pipe.set_incremental(false);
    let r = bench("snapshot rebuild (profile push) x10k", 3, 30, || {
        for i in 0..EDGE_BATCH {
            push(&mut table, i);
            black_box(pipe.prepare(
                &table,
                &peers,
                &no_suspects,
                0,
                &links,
                frames[0].origin,
                10.0,
                200.0,
            ));
        }
    });
    r.print_throughput(EDGE_BATCH as f64, "rebuilds");
    json.push((r.clone(), Some(per_op_ns(&r, EDGE_BATCH as f64))));
    pipe.set_incremental(true);

    // Device-level decision on a device-local frame: the privacy
    // short-circuit is the cheapest path and must stay that way.
    let mut dds_dev = PolicyKind::Dds.build(1);
    let mut private_frame = img(7);
    private_frame.constraint =
        Constraint::for_app(AppId(1), 800.0, PrivacyClass::DeviceLocal, 2);
    let pctx = DeviceCtx {
        now_ms: 10.0,
        img: &private_frame,
        local: LocalSnapshot {
            node: NodeId(1),
            busy_containers: 1,
            warm_containers: 2,
            queued_images: 1,
            cpu_load_pct: 10.0,
            battery_pct: None,
        },
        predictor: &pred,
        edge_suspected: false,
    };
    let r = bench("decide_device(device_local) x10k", 3, 30, || {
        for _ in 0..DEC_BATCH {
            black_box(dds_dev.decide_device(black_box(&pctx)));
        }
    });
    r.print_throughput(DEC_BATCH as f64, "decisions");
    json.push((r.clone(), Some(per_op_ns(&r, DEC_BATCH as f64))));

    section("container pool");
    let r = bench("submit+complete cycle x1k", 3, 30, || {
        let mut pool = ContainerPool::new(profile_for(NodeClass::EdgeServer), 4);
        let mut now = 0.0;
        for t in 0..1_000u64 {
            if let Some(a) = pool.submit(img(t), now) {
                now = a.done_at_ms;
                pool.complete(a.container, a.task, now);
            }
        }
        black_box(pool.stats());
    });
    r.print_throughput(1_000.0, "cycles");
    json.push((r.clone(), Some(per_op_ns(&r, 1_000.0))));

    section("pending-event queue (calendar wheel vs binary heap)");
    // The engine-twin structures under the engine's own key discipline:
    // `(at_ms, seq)` with same-timestamp events in insertion order.
    // Timestamps spread over 2× the wheel's in-window span so the
    // overflow level and the window jump are both on the measured path.
    for &n in &[1_000usize, 100_000] {
        let at = |i: usize| (i % 4096) as f64 * 0.5;
        let r = bench(&format!("wheel push+pop x{n}"), 2, 10, || {
            let mut q = edge_dds::sim::CalendarQueue::new(1.0, 1024);
            for i in 0..n {
                q.push(at(i), i as u64, i as u32);
            }
            while let Some(e) = q.pop() {
                black_box(e);
            }
        });
        r.print_throughput(n as f64, "push+pop");
        json.push((r.clone(), Some(per_op_ns(&r, n as f64))));
        let r = bench(&format!("heap push+pop x{n}"), 2, 10, || {
            // f64 keys are non-negative here, so the bit pattern orders
            // like the float — the classic heap's comparator in miniature.
            let mut q: BinaryHeap<Reverse<(u64, u64, u32)>> = BinaryHeap::new();
            for i in 0..n {
                q.push(Reverse((at(i).to_bits(), i as u64, i as u32)));
            }
            while let Some(e) = q.pop() {
                black_box(e);
            }
        });
        r.print_throughput(n as f64, "push+pop");
        json.push((r.clone(), Some(per_op_ns(&r, n as f64))));
    }

    section("record store (dense slab vs hashmap)");
    // The per-frame record lookup that every placed/started/completed
    // transition pays. The dense slab indexes by TaskId directly; the
    // hashmap baseline is the pre-PR-9 cost model (hash + probe per
    // touch).
    const REC_N: u64 = 100_000;
    let mut rec = edge_dds::metrics::Recorder::new();
    for t in 0..REC_N {
        rec.created(&img(t));
    }
    let r = bench("record lookup dense x100k", 2, 10, || {
        let mut live = 0u64;
        for t in 0..REC_N {
            if rec.get(TaskId(t)).is_some() {
                live += 1;
            }
        }
        black_box(live);
    });
    r.print_throughput(REC_N as f64, "lookups");
    json.push((r.clone(), Some(per_op_ns(&r, REC_N as f64))));
    let mut map: HashMap<u64, u64> = HashMap::new();
    for t in 0..REC_N {
        map.insert(t, t);
    }
    let r = bench("record lookup hashmap x100k (baseline)", 2, 10, || {
        let mut live = 0u64;
        for t in 0..REC_N {
            if map.contains_key(&t) {
                live += 1;
            }
        }
        black_box(live);
    });
    r.print_throughput(REC_N as f64, "lookups");
    json.push((r.clone(), Some(per_op_ns(&r, REC_N as f64))));

    section("wire codec");
    let msg = Message::Image(img(42));
    let mut buf = Vec::with_capacity(256);
    const CODEC_BATCH: u32 = 10_000;
    let r = bench("encode+decode x10k", 3, 30, || {
        for _ in 0..CODEC_BATCH {
            wire::encode(black_box(&msg), &mut buf);
            black_box(wire::decode(&buf).unwrap());
        }
    });
    r.print_throughput(CODEC_BATCH as f64, "roundtrips");
    json.push((r.clone(), Some(per_op_ns(&r, CODEC_BATCH as f64))));

    // The two decode surfaces measured separately (DESIGN.md §9). The
    // receive hot path is a *forwarded* frame carrying a visited path —
    // the owned decode allocates a Vec per frame there, the borrowed view
    // allocates nothing. `scripts/bench_check` gates the decode numbers.
    let fwd = Message::Forward {
        img: img(42),
        from_edge: NodeId(3),
        route: edge_dds::core::message::ForwardRoute {
            ttl: 3,
            visited: vec![NodeId(0), NodeId(3), NodeId(7), NodeId(9)],
        },
    };
    let mut fwd_buf = Vec::with_capacity(256);
    wire::encode(&fwd, &mut fwd_buf);
    let r = bench("encode x10k", 3, 30, || {
        for _ in 0..CODEC_BATCH {
            black_box(wire::encode(black_box(&fwd), &mut buf));
        }
    });
    r.print_throughput(CODEC_BATCH as f64, "encodes");
    json.push((r.clone(), Some(per_op_ns(&r, CODEC_BATCH as f64))));
    let r = bench("decode(owned, forward+path) x10k", 3, 30, || {
        for _ in 0..CODEC_BATCH {
            black_box(wire::decode(black_box(&fwd_buf)).unwrap());
        }
    });
    r.print_throughput(CODEC_BATCH as f64, "decodes");
    json.push((r.clone(), Some(per_op_ns(&r, CODEC_BATCH as f64))));
    let r = bench("view(borrowed, forward+path) x10k", 3, 30, || {
        for _ in 0..CODEC_BATCH {
            // Inspect the path in place — what the edge receive loop does
            // for loop rejection — without materialising the Vec.
            let v = wire::view(black_box(&fwd_buf)).unwrap();
            if let wire::MessageView::Forward { visited, .. } = &v {
                black_box(visited.contains(NodeId(5)));
            }
            black_box(v);
        }
    });
    r.print_throughput(CODEC_BATCH as f64, "views");
    json.push((r.clone(), Some(per_op_ns(&r, CODEC_BATCH as f64))));

    section("transport: single sends vs batched backhaul");
    // A drain-only peer on localhost; the sender pushes 1k small frames
    // per iteration either as 1k individual sends (one write syscall
    // each) or as one coalesced batch (flushes at BATCH_FLUSH_BYTES).
    const SEND_BATCH: u32 = 1_000;
    let pool = edge_dds::net::BufPool::new();
    let server = edge_dds::net::transport::serve_pooled("127.0.0.1:0", pool.clone(), |mut conn| {
        while conn.recv_frame().is_ok() {}
    })
    .expect("bench sink server");
    let mut conn = edge_dds::net::transport::FramedConn::connect_pooled(server.local_addr, &pool)
        .expect("bench sender");
    let summaries: Vec<Message> = (0..SEND_BATCH)
        .map(|i| {
            Message::EdgeSummary(edge_dds::core::message::EdgeSummary {
                edge: NodeId(i % 7),
                busy_containers: i % 3,
                warm_containers: 4,
                queued_images: i % 5,
                cpu_load_pct: 12.5,
                device_idle_containers: 3,
                sent_ms: i as f64,
                hops: 0,
                via: NodeId(i % 7),
            })
        })
        .collect();
    let r = bench("send single x1k msgs", 3, 30, || {
        for m in &summaries {
            conn.send(m).expect("single send");
        }
    });
    r.print_throughput(SEND_BATCH as f64, "msgs");
    json.push((r.clone(), Some(per_op_ns(&r, SEND_BATCH as f64))));
    let r = bench("send_batch x1k msgs", 3, 30, || {
        conn.send_batch(summaries.iter()).expect("batched send");
    });
    r.print_throughput(SEND_BATCH as f64, "msgs");
    json.push((r.clone(), Some(per_op_ns(&r, SEND_BATCH as f64))));
    drop(conn);
    server.stop();

    section("whole-engine event throughput");
    for (n, interval) in [(1_000u32, 50.0), (1_000, 100.0)] {
        let builder = ScenarioBuilder::paper_testbed(PolicyKind::Dds).workload(WorkloadConfig {
            n_images: n,
            interval_ms: interval,
            size_kb: 29.0,
            size_jitter_kb: 0.0,
            deadline_ms: 5_000.0,
            side_px: 64,
            pattern: ArrivalPattern::Uniform,
        });
        let probe = builder.run();
        let events = probe.events as f64;
        let r = bench(&format!("sim {n} imgs @{interval}ms ({} events)", probe.events), 1, 10, || {
            black_box(builder.run());
        });
        r.print_throughput(events, "events");
        json.push((r.clone(), Some(per_op_ns(&r, events))));
    }

    section("city-scale throughput (million-frame engine pass)");
    // 16 cells × (31 250 diurnal + 2 × 15 625 flash/batch) = exactly 10⁶
    // frames, streamed through the coalesced lazy-arrival path (each
    // per-cell stream is far above the coalesce threshold). One timed
    // run — the entry records frames/s for the trajectory, it is NOT in
    // the bench_check gate (whole-sim numbers carry scheduler jitter).
    // `CITY_BENCH_IMAGES` scales the diurnal stream down for quick local
    // runs; the recorded name always reflects the actual frame count.
    let city_images: u32 = std::env::var("CITY_BENCH_IMAGES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(31_250);
    let city = ScenarioBuilder::new(edge_dds::experiments::city_config(
        16,
        edge_dds::net::FederationShape::Mesh,
        city_images,
    ))
    .seed(42)
    .max_events(edge_dds::experiments::CITY_MAX_EVENTS);
    let probe = city.run();
    let frames = probe.summary.total as f64;
    println!("city probe: {} frames, {} events", probe.summary.total, probe.events);
    let r = bench(&format!("city 16-cell {} frames", probe.summary.total), 0, 1, || {
        black_box(city.run());
    });
    r.print_throughput(frames, "frames");
    json.push((r.clone(), Some(per_op_ns(&r, frames))));

    let out = std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_9.json".to_string());
    match write_bench_json(&out, "hotpath", &json) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
    println!("hotpath bench done");
}
