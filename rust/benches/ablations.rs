//! Bench target: ablations of the DDS design choices called out in
//! DESIGN.md:
//!
//! 1. **Availability check** (DDS vs DDS-no-avail) — the paper's staleness
//!    compensation ("only offloads the task to that device if containers
//!    are available").
//! 2. **Profile-driven vs blind** (DDS vs round-robin vs random).
//! 3. **UP push cadence** (profile_period_ms sweep — the paper uses 20 ms).
//! 4. **Staleness tolerance** (max_staleness_ms sweep).
//! 5. **Network loss** (UDP image pushes dropped with probability p).
//!
//! Run: `cargo bench --bench ablations`

#[path = "common/mod.rs"]
mod common;

use common::section;
use edge_dds::sim::ArrivalPattern;
use edge_dds::config::WorkloadConfig;
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::ScenarioBuilder;

fn wl(n: u32, interval: f64, deadline: f64) -> WorkloadConfig {
    WorkloadConfig {
        n_images: n,
        interval_ms: interval,
        size_kb: 29.0,
        size_jitter_kb: 0.0,
        deadline_ms: deadline,
        side_px: 64,
            pattern: ArrivalPattern::Uniform,
    }
}

fn main() {
    let base = ScenarioBuilder::paper_testbed(PolicyKind::Dds).workload(wl(1_000, 50.0, 5_000.0));

    section("ablation 1+2: policy family at 1000 imgs @50ms, 5s deadline");
    println!("{:<16} {:>8} {:>8} {:>8} {:>10} {:>12}", "policy", "met", "missed", "dropped", "local%", "p90 ms");
    let mut dds_met = 0;
    let mut noavail_met = 0;
    for r in base.sweep_policies(&PolicyKind::ALL) {
        let p90 = r.summary.latency.as_ref().map(|l| l.p90).unwrap_or(0.0);
        println!(
            "{:<16} {:>8} {:>8} {:>8} {:>9.1}% {:>12.1}",
            r.policy.as_str(),
            r.summary.met,
            r.summary.missed,
            r.summary.dropped,
            r.summary.local_fraction * 100.0,
            p90
        );
        match r.policy {
            PolicyKind::Dds => dds_met = r.summary.met,
            PolicyKind::DdsNoAvail => noavail_met = r.summary.met,
            _ => {}
        }
    }
    println!(
        "availability check gain: {dds_met} vs {noavail_met} met ({:+})",
        dds_met as i64 - noavail_met as i64
    );

    section("ablation 3: UP push cadence (paper: 20 ms)");
    println!("{:>14} {:>8}", "period ms", "met");
    for period in [5.0, 20.0, 100.0, 500.0, 2_000.0] {
        let mut b = base.clone();
        b.config_mut().profile_period_ms = period;
        // Staleness cap must admit at least one period.
        b.config_mut().max_staleness_ms = b.config_mut().max_staleness_ms.max(period * 2.0);
        println!("{:>14} {:>8}", period, b.run().met());
    }

    section("ablation 4: staleness tolerance for offload decisions");
    println!("{:>14} {:>8}", "staleness ms", "met");
    for staleness in [25.0, 50.0, 100.0, 200.0, 1_000.0, 10_000.0] {
        let mut b = base.clone();
        b.config_mut().max_staleness_ms = staleness;
        println!("{:>14} {:>8}", staleness, b.run().met());
    }

    section("extension: energy-aware scheduling (battery-powered R2)");
    // R2 runs on a battery; compare plain DDS vs dds-energy on met count
    // and energy drawn from the pack (paper §VI future work).
    println!("{:<14} {:>8} {:>14} {:>12}", "policy", "met", "consumed mWh", "battery %");
    for policy in [PolicyKind::Dds, PolicyKind::DdsEnergy] {
        let mut b = base.clone().policy(policy);
        b.config_mut().devices[1].battery = true;
        let r = b.run();
        let (_, pct, mwh) = r.batteries[0];
        println!("{:<14} {:>8} {:>14.2} {:>11.2}%", policy.as_str(), r.summary.met, mwh, pct);
    }

    section("ablation 5: UDP image loss");
    println!("{:>10} {:>8} {:>8}", "loss", "met", "dropped");
    for loss in [0.0, 0.01, 0.05, 0.1, 0.25] {
        let mut b = base.clone();
        b.config_mut().network.loss_prob = loss;
        let r = b.run();
        println!("{:>10} {:>8} {:>8}", loss, r.summary.met, r.summary.dropped);
    }

    section("extension: arrival processes (same long-run rate)");
    println!("{:<12} {:>8} {:>12}", "pattern", "met", "p90 ms");
    for (name, pattern) in [
        ("uniform", ArrivalPattern::Uniform),
        ("poisson", ArrivalPattern::Poisson),
        ("bursty:10", ArrivalPattern::Bursty { burst: 10 }),
    ] {
        let mut b = base.clone();
        b.config_mut().workload.pattern = pattern;
        let r = b.run();
        println!(
            "{:<12} {:>8} {:>12.0}",
            name,
            r.summary.met,
            r.summary.latency.as_ref().map(|l| l.p90).unwrap_or(0.0)
        );
    }

    println!("\nablations done");
}
