//! Bench target: regenerate **Figures 5–8** of the paper (whole-system
//! scenario sweeps) and report the paper-shape checks.
//!
//! Run: `cargo bench --bench figures`
//! Fast subset: `cargo bench --bench figures -- --quick` (fig5 single
//! interval + fig7 + fig8 single load row).

#[path = "common/mod.rs"]
mod common;

use common::section;
use edge_dds::experiments::figures::{render_fig8, render_policy_grid};
use edge_dds::experiments::{fig5, fig6, fig7, fig8, render_comparisons};
use edge_dds::scheduler::PolicyKind;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let seed = 42;

    section("Fig 7: CPU load vs container processing time");
    let f7: Vec<_> = fig7().into_iter().map(|r| r.comparison).collect();
    print!("{}", render_comparisons("Fig 7", "load %", &f7));
    assert!(f7.iter().all(|c| c.rel_err() < 1e-6), "Fig 7 must match exactly");

    section("Fig 5: 50 images, 4 intervals x constraint sweep x 4 policies");
    let t = std::time::Instant::now();
    let rows5 = fig5(seed);
    print!("{}", render_policy_grid("Fig 5", &rows5));
    println!("fig5 regenerated in {:.2} s", t.elapsed().as_secs_f64());
    check_shapes(&rows5, 50);

    if !quick {
        section("Fig 6: 1000 images, 2 intervals x constraint sweep x 4 policies");
        let t = std::time::Instant::now();
        let rows6 = fig6(seed);
        print!("{}", render_policy_grid("Fig 6", &rows6));
        println!("fig6 regenerated in {:.2} s", t.elapsed().as_secs_f64());
        check_shapes(&rows6, 1000);
        check_fig6_crossover(&rows6);
    }

    section("Fig 8: DDS vs DDS+R2 under edge CPU stress");
    let t = std::time::Instant::now();
    let rows8 = fig8(seed);
    print!("{}", render_fig8(&rows8));
    println!("fig8 regenerated in {:.2} s", t.elapsed().as_secs_f64());
    // Paper shapes: load hurts; the extra device helps.
    for d in [5_000.0, 10_000.0] {
        let series: Vec<_> = rows8.iter().filter(|r| r.deadline_ms == d).collect();
        let first = series.first().unwrap();
        let last = series.last().unwrap();
        assert!(
            last.dds_met <= first.dds_met,
            "load should not increase met count (deadline {d})"
        );
        assert!(
            first.dds_with_r2_met > first.dds_met,
            "R2 must help at load 0 (deadline {d})"
        );
    }

    println!("\nall figures regenerated");
}

/// The paper's qualitative claims, asserted over a regenerated grid.
fn check_shapes(rows: &[edge_dds::experiments::Fig5Row], total: usize) {
    let get = |r: &edge_dds::experiments::Fig5Row, k: PolicyKind| {
        r.met.iter().find(|(p, _)| *p == k).map(|(_, m)| *m).unwrap_or(0)
    };
    let mut dds_wins = 0usize;
    let mut cells = 0usize;
    for r in rows {
        let (aor, aoe, eods, dds) = (
            get(r, PolicyKind::Aor),
            get(r, PolicyKind::Aoe),
            get(r, PolicyKind::Eods),
            get(r, PolicyKind::Dds),
        );
        assert!(aor <= total && aoe <= total && eods <= total && dds <= total);
        // "the edge server always performs better than the end device"
        assert!(aoe + 2 >= aor, "AOE should not lose badly to AOR: {r:?}");
        // Sub-200 ms constraints are infeasible for everyone.
        if r.deadline_ms < 200.0 {
            assert_eq!(aor + aoe + eods + dds, 0, "sub-200ms must all fail");
        }
        cells += 1;
        if dds >= eods {
            dds_wins += 1;
        }
    }
    // "The Dynamic Distributed Scheduler is better than the Even Odd
    // Distributed Scheduler, except when the edge server is heavily
    // loaded" — DDS should win or tie in the majority of cells.
    assert!(
        dds_wins * 2 > cells,
        "DDS should beat EODS in most cells: {dds_wins}/{cells}"
    );
}

/// Fig. 6's second observation: with loose constraints EODS can overtake
/// DDS (queue hoarding) — verify the crossover exists at interval 50 ms.
fn check_fig6_crossover(rows: &[edge_dds::experiments::Fig5Row]) {
    let get = |r: &edge_dds::experiments::Fig5Row, k: PolicyKind| {
        r.met.iter().find(|(p, _)| *p == k).map(|(_, m)| *m).unwrap_or(0)
    };
    let tight_dds_wins = rows.iter().any(|r| {
        r.interval_ms == 50.0
            && r.deadline_ms <= 10_000.0
            && get(r, PolicyKind::Dds) > get(r, PolicyKind::Eods)
    });
    assert!(tight_dds_wins, "DDS should win somewhere in the tight regime");
}
