//! Bench target: regenerate **Tables II–VI** of the paper (container
//! profiles) and time the regeneration itself.
//!
//! Run: `cargo bench --bench tables`

#[path = "common/mod.rs"]
mod common;

use common::{bench, black_box, section};
use edge_dds::experiments::{table2, table3, table4, table5, table6};

fn main() {
    section("Table II: runtime vs image size (edge server)");
    let t2 = table2();
    print!("{}", t2.render());
    assert!(t2.max_rel_err() < 1e-6, "Table II must match exactly");

    section("Table III: cold-start profile (edge server)");
    let (t3a, t3b) = table3();
    print!("{}\n{}", t3a.render(), t3b.render());

    section("Table IV: cold-start profile (Raspberry Pi)");
    let (t4a, t4b) = table4();
    print!("{}\n{}", t4a.render(), t4b.render());

    section("Table V: warm-container profile (edge server)");
    let (t5a, t5b) = table5();
    print!("{}\n{}", t5a.render(), t5b.render());

    section("Table VI: warm-container profile (Raspberry Pi)");
    let (t6a, t6b) = table6();
    print!("{}\n{}", t6a.render(), t6b.render());

    section("regeneration cost");
    bench("table2 regen", 2, 20, || {
        black_box(table2());
    })
    .print();
    bench("table5 regen (50-image micro-sim x8)", 2, 20, || {
        black_box(table5());
    })
    .print();
    bench("table6 regen (50-image micro-sim x6)", 2, 20, || {
        black_box(table6());
    })
    .print();

    println!("\nall tables regenerated");
}
