//! Shared mini-harness for the `harness = false` bench targets (criterion
//! is not in the offline crate set). Provides warmup + repeated timing with
//! mean/p50/min reporting, and a tiny black_box.
#![allow(dead_code)] // each bench binary uses a different subset

use std::hint;
use std::time::Instant;

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    hint::black_box(x)
}

/// Timing result for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_us: f64,
    pub p50_us: f64,
    pub min_us: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>8} iters  mean {:>12.2} us  p50 {:>12.2} us  min {:>12.2} us",
            self.name, self.iters, self.mean_us, self.p50_us, self.min_us
        );
    }

    /// Throughput helper for per-item benches.
    pub fn print_throughput(&self, items_per_iter: f64, unit: &str) {
        let per_sec = items_per_iter / (self.mean_us / 1e6);
        println!(
            "{:<44} {:>8} iters  mean {:>12.2} us  {:>14.0} {unit}/s",
            self.name, self.iters, self.mean_us, per_sec
        );
    }
}

/// Run `f` `iters` times after `warmup` runs; report per-iteration stats.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_us: mean,
        p50_us: samples[samples.len() / 2],
        min_us: samples[0],
    }
}

/// Section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Per-operation nanoseconds for a bench that runs `items_per_iter`
/// operations per iteration.
pub fn per_op_ns(r: &BenchResult, items_per_iter: f64) -> f64 {
    r.mean_us * 1_000.0 / items_per_iter
}

/// Machine-readable bench summary (`BENCH_<n>.json`): the perf
/// trajectory record CI uploads as an artifact. Hand-rolled JSON — serde
/// is not in the offline crate set.
pub fn write_bench_json(
    path: &str,
    bench: &str,
    entries: &[(BenchResult, Option<f64>)],
) -> std::io::Result<()> {
    let results: Vec<String> = entries
        .iter()
        .map(|(r, per_op)| {
            let per_op = per_op
                .map(|ns| format!(r#","per_op_ns":{ns:.2}"#))
                .unwrap_or_default();
            format!(
                r#"{{"name":"{}","iters":{},"mean_us":{:.3},"p50_us":{:.3},"min_us":{:.3}{}}}"#,
                r.name.replace('"', "'"),
                r.iters,
                r.mean_us,
                r.p50_us,
                r.min_us,
                per_op
            )
        })
        .collect();
    std::fs::write(
        path,
        format!(
            "{{\"bench\":\"{bench}\",\"results\":[\n  {}\n]}}\n",
            results.join(",\n  ")
        ),
    )
}
