//! End-to-end serving driver (the repo's headline validation run,
//! EXPERIMENTS.md §E2E): load the real AOT face-detection artifacts,
//! serve batched detection requests through the **full live stack**
//! (client socket → edge IS → APe/DDS → device APr → PJRT container →
//! result relay), and report latency/throughput per image-size variant.
//!
//! Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --offline --example e2e_serving
//! ```

use std::time::{Duration, Instant};

use edge_dds::sim::ArrivalPattern;
use edge_dds::config::{SystemConfig, WorkloadConfig};
use edge_dds::core::NodeId;
use edge_dds::live::LiveCluster;
use edge_dds::runtime::RuntimeService;
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::ImageStream;
use edge_dds::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    edge_dds::util::logger::init();
    let artifacts = std::env::var("EDGE_DDS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // --- Stage 1: raw model serving (no scheduler) — Table II analogue ---
    println!("== stage 1: raw PJRT serving, per image-size variant ==");
    let runtime = RuntimeService::spawn(&artifacts)?;
    println!("{:>6} {:>12} {:>12} {:>12}", "side", "mean ms", "min ms", "imgs/s");
    for &side in runtime.sides().to_vec().iter() {
        let mut times = Vec::new();
        for i in 0..10u64 {
            let (_det, ms) = runtime.detect_synth(side, i)?;
            times.push(ms);
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        println!("{:>6} {:>12.2} {:>12.2} {:>12.1}", side, mean, min, 1e3 / mean);
    }

    // --- Stage 2: full-stack batched serving through the live cluster ---
    println!("\n== stage 2: full-stack serving (client→edge→device→PJRT) ==");
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Dds;
    cfg.workload = WorkloadConfig {
        n_images: 60,
        interval_ms: 50.0,
        size_kb: 29.0,
        size_jitter_kb: 0.0,
        deadline_ms: 5_000.0,
        side_px: 64,
            pattern: ArrivalPattern::Uniform,
    };

    let cluster = LiveCluster::start(&cfg, RuntimeService::spawn(&artifacts)?)?;
    std::thread::sleep(Duration::from_millis(200)); // joins settle

    let frames = ImageStream::new(cfg.workload, NodeId(1), SplitMix64::new(99)).generate();
    let n = frames.len();
    let t0 = Instant::now();
    cluster.stream(frames)?;
    let summary = cluster.wait(Duration::from_secs(180));
    let wall = t0.elapsed().as_secs_f64();

    let lat = summary.latency.as_ref().expect("completed tasks");
    println!(
        "served {n} requests in {wall:.1} s → {:.1} req/s sustained",
        summary.total as f64 / wall
    );
    println!(
        "e2e latency: mean {:.1} ms  p50 {:.1}  p90 {:.1}  p99 {:.1}  max {:.1}",
        lat.mean, lat.p50, lat.p90, lat.p99, lat.max
    );
    println!(
        "met {}/{} within {} ms; {:.0}% executed at the camera device",
        summary.met,
        summary.total,
        cfg.workload.deadline_ms,
        summary.local_fraction * 100.0
    );
    if let Some(p) = &summary.process {
        println!("container (PJRT) time: mean {:.1} ms  p90 {:.1} ms", p.mean, p.p90);
    }
    cluster.shutdown();

    // --- Stage 3: the same workload in virtual mode for comparison ---
    println!("\n== stage 3: same workload, virtual mode (calibrated sim) ==");
    let report = edge_dds::sim::ScenarioBuilder::new(cfg).run();
    let s = &report.summary;
    println!(
        "sim: met {}/{}; mean e2e {:.1} ms (paper-calibrated container model)",
        s.met,
        s.total,
        s.latency.as_ref().map(|l| l.mean).unwrap_or(0.0)
    );
    println!("\ne2e serving driver done — record these numbers in EXPERIMENTS.md §E2E");
    Ok(())
}
