//! Quickstart: run the paper's testbed (edge server + 2 Raspberry Pis) in
//! virtual mode under all four scheduling algorithms and print who meets
//! the 5-second constraint.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use edge_dds::sim::ArrivalPattern;
use edge_dds::config::WorkloadConfig;
use edge_dds::metrics::writer::summary_json;
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::ScenarioBuilder;

fn main() {
    edge_dds::util::logger::init();

    // The paper's Fig. 5 style workload: 50 frames every 100 ms, 5 s
    // end-to-end constraint, 29 KB test image.
    let workload = WorkloadConfig {
        n_images: 50,
        interval_ms: 100.0,
        size_kb: 29.0,
        size_jitter_kb: 0.0,
        deadline_ms: 5_000.0,
        side_px: 64,
            pattern: ArrivalPattern::Uniform,
    };

    println!("edge-dds quickstart — 50 images @100 ms, 5 s constraint\n");
    println!("{:<8} {:>6} {:>8} {:>10} {:>12} {:>12}", "policy", "met", "missed", "local%", "mean ms", "p90 ms");

    for policy in PolicyKind::PAPER {
        let report = ScenarioBuilder::paper_testbed(policy).workload(workload).run();
        let s = &report.summary;
        let (mean, p90) = s
            .latency
            .as_ref()
            .map(|l| (l.mean, l.p90))
            .unwrap_or((0.0, 0.0));
        println!(
            "{:<8} {:>6} {:>8} {:>9.0}% {:>12.1} {:>12.1}",
            policy.as_str(),
            s.met,
            s.missed,
            s.local_fraction * 100.0,
            mean,
            p90
        );
    }

    // Machine-readable single-run output.
    let dds = ScenarioBuilder::paper_testbed(PolicyKind::Dds).workload(workload).run();
    println!("\n{}", summary_json("dds", &dds.summary));
    println!(
        "\nsimulated {:.1} s of cluster time in {:.1} ms of wall time ({} events)",
        dds.virtual_ms / 1e3,
        dds.wall_us as f64 / 1e3,
        dds.events
    );
}
