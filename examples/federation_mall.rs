//! Federation mall scenario: the paper's mall (§III-C) scaled to a
//! two-wing shopping center, one federation cell per wing. The east wing
//! hosts the event of the day — its camera streams a heavy frame load
//! while its edge server is saturated by other tenants — and DDS sheds
//! the overflow over the backhaul to the idle west-wing cell.
//!
//! Exercises: `[[cell]]`-style multi-cell config, inter-edge MP gossip,
//! the third (federation) decision level, and cross-cell result relay.
//!
//! ```bash
//! cargo run --release --offline --example federation_mall
//! ```

use edge_dds::config::{CellConfig, DeviceConfig, SystemConfig, WorkloadConfig};
use edge_dds::core::NodeClass;
use edge_dds::metrics::writer::summary_json;
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::{ArrivalPattern, ScenarioBuilder};

fn mall_config(cells: usize) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Dds;
    // One edge server per wing; the east wing (cell 0) is loaded by other
    // mall tenants (digital signage, analytics, POS backends).
    cfg.cells = (0..cells)
        .map(|c| CellConfig {
            warm_containers: 4,
            cpu_load_pct: if c == 0 { 75.0 } else { 0.0 },
        })
        .collect();
    cfg.devices = (0..cells)
        .flat_map(|c| {
            [
                DeviceConfig {
                    class: NodeClass::RaspberryPi,
                    warm_containers: 2,
                    camera: c == 0, // the event is in the east wing
                    cpu_load_pct: 0.0,
                    location: (1.0, 0.0),
                    battery: false,
                    cell: c as u32,
                },
                DeviceConfig {
                    class: NodeClass::SmartPhone,
                    warm_containers: 1,
                    camera: false,
                    cpu_load_pct: 10.0,
                    location: (2.0, 5.0),
                    battery: false,
                    cell: c as u32,
                },
            ]
        })
        .collect();
    cfg.workload = WorkloadConfig {
        n_images: 400,
        interval_ms: 40.0,
        size_kb: 29.0,
        size_jitter_kb: 4.0,
        deadline_ms: 2_000.0,
        side_px: 64,
        pattern: ArrivalPattern::Bursty { burst: 8 }, // motion-triggered
    };
    cfg
}

fn main() {
    edge_dds::util::logger::init();
    println!("federation mall — 400 bursty frames @40 ms, 2 s constraint\n");

    for cells in [1usize, 2] {
        let report = ScenarioBuilder::new(mall_config(cells)).seed(42).run();
        let s = &report.summary;
        println!(
            "{} wing(s): {}",
            cells,
            summary_json(&format!("mall-{cells}cell"), s)
        );
        println!(
            "  met {}/{} | cross-cell forwards: {} | local fraction {:.2}\n",
            s.met, s.total, s.forwarded, s.local_fraction
        );
    }

    println!("The second wing absorbs overflow the loaded east-wing cell");
    println!("cannot serve — compare the met counts and forward totals.");
}
