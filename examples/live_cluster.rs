//! Live cluster: the paper's deployment on real threads and real localhost
//! sockets, with containers executing the real AOT-compiled face-detection
//! model via PJRT. A mobile-user client connects over TCP exactly like the
//! paper's Android app. The workload registers two applications (a strict
//! detector and best-effort analytics), and the run report prints the same
//! per-app met-fraction table the sim experiment writers render.
//!
//! Requires `make artifacts` first.
//!
//! ```bash
//! cargo run --release --offline --example live_cluster
//! ```

use std::time::Duration;

use edge_dds::client::UserClient;
use edge_dds::config::{AppSpec, SystemConfig};
use edge_dds::core::PrivacyClass;
use edge_dds::live::LiveCluster;
use edge_dds::metrics::render_per_app;
use edge_dds::runtime::RuntimeService;
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::{ArrivalPattern, ScenarioBuilder};

fn main() -> anyhow::Result<()> {
    edge_dds::util::logger::init();

    let artifacts = std::env::var("EDGE_DDS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    println!("compiling artifacts from {artifacts}/ ...");
    let runtime = RuntimeService::spawn(&artifacts)?;
    println!("compiled variants: {:?}", runtime.sides());

    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Dds;
    // Two tenants on the same cluster (DESIGN.md §Constraints & QoS):
    // a latency-critical detector and best-effort analytics.
    cfg.apps = vec![
        AppSpec {
            name: "detector".into(),
            deadline_ms: 2_000.0,
            privacy: PrivacyClass::CellLocal,
            priority: 2,
            n_images: 20,
            interval_ms: 150.0,
            size_kb: 29.0,
            side_px: 64,
            pattern: ArrivalPattern::Uniform,
            weight: None,
            admit_rate_per_s: None,
        },
        AppSpec {
            name: "analytics".into(),
            deadline_ms: 10_000.0,
            privacy: PrivacyClass::Open,
            priority: 0,
            n_images: 10,
            interval_ms: 300.0,
            size_kb: 29.0,
            side_px: 64,
            pattern: ArrivalPattern::Uniform,
            weight: None,
            admit_rate_per_s: None,
        },
    ];

    println!("starting live cluster (edge + {} devices) ...", cfg.devices.len());
    let cluster = LiveCluster::start(&cfg, runtime)?;
    println!("edge server listening on {}", cluster.edge_addr);
    // Each cell serves a plaintext introspection exposition over TCP
    // (DESIGN.md §Observability) — scrape it with curl or any client.
    for (edge, addr) in cluster.introspect_addrs() {
        println!("introspection: {edge} http://{addr}/metrics");
    }

    // A mobile user connects over a real TCP socket, like the paper's
    // Android client, and requests the face-detection application.
    let mut user = UserClient::connect(cluster.edge_addr)?;
    user.request(1, (1.0, 0.0), 2_000.0, 20, 150.0)?;
    println!("user request sent (app=face-detect, 20 frames @150 ms)");

    // Let joins/profile pushes settle, then stream the per-app camera
    // frames — the same derivation the simulator uses (one stream per
    // registered app, disjoint TaskId blocks).
    std::thread::sleep(Duration::from_millis(200));
    let streams = ScenarioBuilder::camera_streams(&cfg);
    let n: usize = streams.iter().map(|(_, f)| f.len()).sum();
    for (device_index, frames) in streams {
        cluster.stream_to(device_index, frames)?;
    }
    println!("streaming {n} frames across {} app(s)", cfg.effective_apps().len());

    let summary = cluster.wait(Duration::from_secs(120));
    println!(
        "\nlive run: met {}/{} (p90 e2e {:.1} ms, mean container time {:.1} ms)",
        summary.met,
        summary.total,
        summary.latency.as_ref().map(|l| l.p90).unwrap_or(0.0),
        summary.process.as_ref().map(|p| p.mean).unwrap_or(0.0),
    );
    // Per-app rows — identical columns to the sim writer's SLO table.
    let names: Vec<String> = cfg.effective_apps().iter().map(|a| a.name.clone()).collect();
    print!("{}", render_per_app(&summary, &names));

    // One end-of-run scrape of cell 0's introspection endpoint.
    if let Some((edge, addr)) = cluster.introspect_addrs().first() {
        use std::io::Read;
        let mut text = String::new();
        std::net::TcpStream::connect(addr)?.read_to_string(&mut text)?;
        let body = text.split("\r\n\r\n").nth(1).unwrap_or(&text);
        println!("\nintrospection scrape of {edge}:\n{body}");
    }

    // Non-blocking read of anything the edge pushed to the user.
    drop(user);
    cluster.shutdown();
    println!("cluster shut down cleanly");
    Ok(())
}
