//! Live cluster: the paper's deployment on real threads and real localhost
//! sockets, with containers executing the real AOT-compiled face-detection
//! model via PJRT. A mobile-user client connects over TCP exactly like the
//! paper's Android app.
//!
//! Requires `make artifacts` first.
//!
//! ```bash
//! cargo run --release --offline --example live_cluster
//! ```

use std::time::Duration;

use edge_dds::client::UserClient;
use edge_dds::sim::ArrivalPattern;
use edge_dds::config::{SystemConfig, WorkloadConfig};
use edge_dds::core::NodeId;
use edge_dds::live::LiveCluster;
use edge_dds::runtime::RuntimeService;
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::ImageStream;
use edge_dds::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    edge_dds::util::logger::init();

    let artifacts = std::env::var("EDGE_DDS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    println!("compiling artifacts from {artifacts}/ ...");
    let runtime = RuntimeService::spawn(&artifacts)?;
    println!("compiled variants: {:?}", runtime.sides());

    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Dds;
    cfg.workload = WorkloadConfig {
        n_images: 30,
        interval_ms: 100.0,
        size_kb: 29.0,
        size_jitter_kb: 0.0,
        deadline_ms: 5_000.0,
        side_px: 64,
            pattern: ArrivalPattern::Uniform,
    };

    println!("starting live cluster (edge + {} devices) ...", cfg.devices.len());
    let cluster = LiveCluster::start(&cfg, runtime)?;
    println!("edge server listening on {}", cluster.edge_addr);

    // A mobile user connects over a real TCP socket, like the paper's
    // Android client, and requests the face-detection application.
    let mut user = UserClient::connect(cluster.edge_addr)?;
    user.request(1, (1.0, 0.0), cfg.workload.deadline_ms, cfg.workload.n_images, cfg.workload.interval_ms)?;
    println!("user request sent (app=face-detect, 30 frames @100 ms)");

    // Let joins/profile pushes settle, then stream camera frames.
    std::thread::sleep(Duration::from_millis(200));
    let frames = ImageStream::new(cfg.workload, NodeId(1), SplitMix64::new(7)).generate();
    let _n = frames.len();
    cluster.stream(frames)?;

    let summary = cluster.wait(Duration::from_secs(120));
    println!(
        "\nlive run: met {}/{} within {} ms (p90 e2e {:.1} ms, mean container time {:.1} ms)",
        summary.met,
        summary.total,
        cfg.workload.deadline_ms,
        summary.latency.as_ref().map(|l| l.p90).unwrap_or(0.0),
        summary.process.as_ref().map(|p| p.mean).unwrap_or(0.0),
    );

    // Non-blocking read of anything the edge pushed to the user.
    drop(user);
    cluster.shutdown();
    println!("cluster shut down cleanly");
    Ok(())
}
