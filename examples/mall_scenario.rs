//! Mall scenario (the paper's §III-C motivation): a crowded mall with
//! several camera devices; a user asks the edge server to find a person.
//! The edge server activates the camera nearest to the user's location and
//! the resulting frame stream is scheduled with DDS while the edge is
//! partially loaded by other tenants.
//!
//! Exercises: location-based activation, heterogeneous device classes,
//! mid-run load changes, pinned (privacy) tasks.
//!
//! ```bash
//! cargo run --release --offline --example mall_scenario
//! ```

use edge_dds::sim::ArrivalPattern;
use edge_dds::config::{DeviceConfig, SystemConfig, WorkloadConfig};
use edge_dds::core::{NodeClass, NodeId};
use edge_dds::scheduler::PolicyKind;
use edge_dds::sim::ScenarioBuilder;

fn main() {
    edge_dds::util::logger::init();

    // Mall floor: three camera RPis at different corners plus a staff
    // phone (no camera) that can absorb offloaded work.
    let mut cfg = SystemConfig::default();
    cfg.policy = PolicyKind::Dds;
    cfg.edge_warm_containers = 4;
    cfg.devices = vec![
        DeviceConfig {
            class: NodeClass::RaspberryPi,
            warm_containers: 2,
            camera: true,
            cpu_load_pct: 0.0,
            location: (0.0, 0.0), // north entrance
            battery: false,
            cell: 0,
        },
        DeviceConfig {
            class: NodeClass::RaspberryPi,
            warm_containers: 2,
            camera: true,
            cpu_load_pct: 20.0,
            location: (50.0, 0.0), // food court
            battery: false,
            cell: 0,
        },
        DeviceConfig {
            class: NodeClass::RaspberryPi,
            warm_containers: 2,
            camera: true,
            cpu_load_pct: 0.0,
            location: (25.0, 40.0), // cinema
            battery: false,
            cell: 0,
        },
        DeviceConfig {
            class: NodeClass::SmartPhone,
            warm_containers: 1,
            camera: false,
            cpu_load_pct: 10.0,
            location: (25.0, 10.0), // security staff phone
            battery: true, // untethered — energy-aware DDS protects it
            cell: 0,
        },
    ];
    cfg.workload = WorkloadConfig {
        n_images: 200,
        interval_ms: 50.0,
        size_kb: 87.0,
        size_jitter_kb: 20.0,
        deadline_ms: 3_000.0,
        side_px: 128,
            pattern: ArrivalPattern::Uniform,
    };

    // The user stands near the food court; the builder streams from the
    // first camera device, so order devices accordingly (nearest first).
    let user_loc = (48.0, 5.0);
    let builder = ScenarioBuilder::new(cfg.clone());
    let topo = builder.topology();
    let nearest = topo.nearest_camera(user_loc).expect("mall has cameras");
    println!("user at {user_loc:?} → activating camera {nearest}");

    // Reorder so the activated camera is the stream origin.
    let idx = (nearest.0 - 1) as usize;
    cfg.devices.swap(0, idx);

    println!("\n-- find-a-person stream: 200 frames @50 ms, 3 s constraint --");
    // Lunch rush: the edge gets busy halfway through the stream.
    let report = ScenarioBuilder::new(cfg.clone())
        .load_at(5_000.0, NodeId(0), 75.0)
        .run();
    let s = &report.summary;
    println!(
        "met {}/{} ({:.0}%), {:.0}% processed at the camera, p90 latency {:.0} ms",
        s.met,
        s.total,
        s.met_fraction() * 100.0,
        s.local_fraction * 100.0,
        s.latency.as_ref().map(|l| l.p90).unwrap_or(0.0)
    );

    println!("\n-- same stream under every policy (lunch-rush load) --");
    println!("{:<14} {:>6} {:>10} {:>12}", "policy", "met", "local%", "p90 ms");
    for policy in PolicyKind::ALL {
        let r = ScenarioBuilder::new(cfg.clone())
            .policy(policy)
            .load_at(5_000.0, NodeId(0), 75.0)
            .run();
        println!(
            "{:<14} {:>6} {:>9.0}% {:>12.0}",
            policy.as_str(),
            r.summary.met,
            r.summary.local_fraction * 100.0,
            r.summary.latency.as_ref().map(|l| l.p90).unwrap_or(0.0)
        );
    }

    // Privacy-constrained tenant: tasks pinned to the camera device
    // (§II "some users may submit tasks only to specific nodes").
    println!("\n-- privacy-pinned stream (never leaves the camera device) --");
    let mut eng = ScenarioBuilder::new(cfg).build();
    // Note: pinned tasks are exercised directly through the scheduler in
    // unit tests; here we show the config-level workload runs unchanged.
    eng.run();
    let s = eng.recorder.summarize();
    println!("pinned-run baseline: met {}/{}", s.met, s.total);
}
