"""L2: the face-detection compute graph the containers run.

Mirrors the paper's container workload (Viola-Jones face detection over an
image) as a JAX pipeline calling the L1 Pallas kernels:

    grayscale → multi-scale pyramid → integral image (pallas)
              → dense Haar cascade (pallas) → fixed-shape summary outputs

Outputs are fixed-shape regardless of image size so the Rust runtime can
decode them uniformly:
    counts[MAX_LEVELS]  — detections (survivor windows) per pyramid level,
                          zero-padded for unused levels
    max_score           — best window score across all levels
    hist[N_BINS]        — histogram of surviving-window scores

This module is build-time only; `aot.py` lowers `detect` once per supported
image size and the Rust L3 never imports Python.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.haar_cascade import cascade_scores
from .kernels.integral_image import integral_image

#: Pyramid levels are halvings down to the smallest side that still fits a
#: window block grid (32 px). 256→4 levels, 128→3, 64→2, 32→1.
MIN_SIDE = 32
MAX_LEVELS = 4
N_BINS = 16
HIST_LO, HIST_HI = 0.0, 8.0

# Grayscale weights (ITU-R BT.601), same as OpenCV's cvtColor default.
_GRAY = jnp.array([0.299, 0.587, 0.114], dtype=jnp.float32)


def n_levels(side: int) -> int:
    n = 0
    while side >= MIN_SIDE and n < MAX_LEVELS:
        n += 1
        side //= 2
    return n


def grayscale(img: jax.Array) -> jax.Array:
    """(H, W, 3) f32 in [0,1] → (H, W) luminance."""
    return jnp.tensordot(img, _GRAY, axes=([-1], [0]))


def downsample2(x: jax.Array) -> jax.Array:
    """2× average-pool downsample (H, W) → (H/2, W/2)."""
    h, w = x.shape
    return x.reshape(h // 2, 2, w // 2, 2).mean(axis=(1, 3))


def _level_summary(score, mask):
    count = jnp.sum(mask)
    max_score = jnp.max(jnp.where(mask > 0, score, -jnp.inf))
    max_score = jnp.where(count > 0, max_score, 0.0)
    s = jnp.clip(score, HIST_LO, HIST_HI - 1e-6)
    idx = jnp.floor((s - HIST_LO) / (HIST_HI - HIST_LO) * N_BINS).astype(jnp.int32)
    onehot = jax.nn.one_hot(idx, N_BINS, dtype=jnp.float32) * mask[..., None]
    hist = jnp.sum(onehot, axis=(0, 1))
    return count, max_score, hist


def detect(img: jax.Array, interpret: bool = True):
    """Full detection pipeline for a square (S, S, 3) image in [0,1].

    Returns (counts[MAX_LEVELS], max_score, hist[N_BINS]) — all f32.
    """
    side = img.shape[0]
    levels = n_levels(side)
    gray = grayscale(img)

    counts = []
    max_scores = []
    hist = jnp.zeros((N_BINS,), dtype=jnp.float32)
    x = gray
    for _ in range(levels):
        s = integral_image(x, interpret=interpret)
        ii = jnp.pad(s, ((1, 0), (1, 0)))
        score, mask = cascade_scores(ii, interpret=interpret)
        c, m, h = _level_summary(score, mask)
        counts.append(c)
        max_scores.append(m)
        hist = hist + h
        x = downsample2(x)

    counts = jnp.stack(counts + [jnp.zeros(())] * (MAX_LEVELS - levels))
    max_score = jnp.max(jnp.stack(max_scores))
    return counts, max_score, hist


def make_detect_fn(interpret: bool = True):
    """A jit-able detect closure (shape specialization happens at lower)."""

    @functools.partial(jax.jit)
    def fn(img):
        return detect(img, interpret=interpret)

    return fn
