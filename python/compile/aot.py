"""AOT compile path: lower the L2 detect graph to HLO **text** artifacts.

HLO text (NOT `.serialize()`): jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which the runtime's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md). Lowered with return_tuple=True —
the Rust side unwraps the tuple.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts --sizes 64 128 256
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_size(side: int) -> str:
    fn = model.make_detect_fn(interpret=True)
    spec = jax.ShapeDtypeStruct((side, side, 3), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", type=int, nargs="+", default=[64, 128, 256])
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"model": "haar-face-detect", "entries": []}
    for side in args.sizes:
        text = lower_size(side)
        name = f"face_{side}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "side": side,
                "file": name,
                "input": {"shape": [side, side, 3], "dtype": "f32"},
                "outputs": [
                    {"name": "counts", "shape": [model.MAX_LEVELS], "dtype": "f32"},
                    {"name": "max_score", "shape": [], "dtype": "f32"},
                    {"name": "hist", "shape": [model.N_BINS], "dtype": "f32"},
                ],
                "levels": model.n_levels(side),
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "bytes": len(text),
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
