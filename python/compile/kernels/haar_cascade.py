"""L1 Pallas kernel: dense Haar-cascade evaluation over sliding windows.

The paper's hot spot is Viola-Jones face detection. The classical algorithm
is a *sequential* early-exit cascade per window — branch-heavy and GPU/TPU
hostile. The TPU re-think (DESIGN.md §Hardware-Adaptation): evaluate every
stage densely over a *block of window positions* as vector arithmetic on
integral-image slices, and replace per-window early exit with a survivor
mask. Rejected windows still flow through the lanes (wasted lanes ≈ the
price of vectorization) but every op is a VPU-friendly fused
multiply-add over contiguous tiles.

Each grid program owns a (BLOCK_P, PW) tile of window origins. It reads the
(BLOCK_P + WIN, W+1) slab of the padded integral image it needs via a
dynamic row slice (the whole `ii` is mapped into the program; on real TPU the
slab is what streams into VMEM: for W=256 that is (16+16)x257x4 ≈ 33 KB).
Rectangle sums are 4 shifted static slices of the slab — no gathers.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .cascade_params import CASCADE, WIN

# Rows of window positions evaluated per grid program.
BLOCK_P = 16


def _box_sums(tile, y, x, h, w, n_rows, n_cols):
    """Sum over rect (x..x+w, y..y+h) for every window origin in the tile.

    ``tile`` is the zero-padded integral image slab; origin (r, c) of the
    rect for window (r, c) is (r + y, c + x) in image coords == the same in
    padded-ii coords for the top-left corner.
    """
    a = tile[y : y + n_rows, x : x + n_cols]                      # top-left
    b = tile[y : y + n_rows, x + w : x + w + n_cols]              # top-right
    c = tile[y + h : y + h + n_rows, x : x + n_cols]              # bottom-left
    d = tile[y + h : y + h + n_rows, x + w : x + w + n_cols]      # bottom-right
    return d - b - c + a


def _cascade_block(tile, n_rows, n_cols):
    """Evaluate the full cascade for an (n_rows, n_cols) block of windows.

    Returns (score, alive): total accumulated stage score and the 0/1
    survivor mask after all stages.
    """
    win_sum = _box_sums(tile, 0, 0, WIN, WIN, n_rows, n_cols)
    # Illumination normalization: the paper's Viola-Jones normalizes by
    # window variance; we normalize rect sums by mean window energy.
    norm = win_sum / float(WIN * WIN) + 1.0

    alive = jnp.ones((n_rows, n_cols), dtype=jnp.float32)
    total = jnp.zeros((n_rows, n_cols), dtype=jnp.float32)
    for stage in CASCADE:
        score = jnp.zeros((n_rows, n_cols), dtype=jnp.float32)
        for feat in stage.features:
            v = jnp.zeros((n_rows, n_cols), dtype=jnp.float32)
            for r in feat.rects:
                v += r.weight * _box_sums(tile, r.y, r.x, r.h, r.w, n_rows, n_cols)
            v = v / (norm * float(WIN * WIN))
            score += feat.amp * jnp.tanh(v - feat.shift)
        # Survivor mask update — dense replacement for early exit.
        alive = alive * (score > stage.threshold).astype(jnp.float32)
        total = total + alive * score
    return total, alive


def _cascade_kernel(ii_ref, score_ref, mask_ref, *, n_cols):
    i = pl.program_id(0)
    # Slab of the padded integral image backing this block of windows:
    # rows [i*BLOCK_P, i*BLOCK_P + BLOCK_P + WIN), all columns.
    tile = ii_ref[pl.ds(i * BLOCK_P, BLOCK_P + WIN), :]
    score, alive = _cascade_block(tile, BLOCK_P, n_cols)
    score_ref[...] = score
    mask_ref[...] = alive


@functools.partial(jax.jit, static_argnames=("interpret",))
def cascade_scores(ii_padded: jax.Array, interpret: bool = True):
    """Dense cascade evaluation.

    Args:
      ii_padded: (H+1, W+1) zero-padded integral image (f32).

    Returns:
      (score, mask): each (H - WIN, W - WIN) f32 — accumulated stage score
      and the survivor mask for every window origin. The last WIN-1..WIN
      rows/cols of origins are intentionally dropped so the position grid
      stays a multiple of BLOCK_P (documented in DESIGN.md).
    """
    hp, wp = ii_padded.shape
    h, w = hp - 1, wp - 1
    n_rows, n_cols = h - WIN, w - WIN
    assert n_rows % BLOCK_P == 0, f"{n_rows} positions not a multiple of {BLOCK_P}"

    kernel = functools.partial(_cascade_kernel, n_cols=n_cols)
    score, mask = pl.pallas_call(
        kernel,
        grid=(n_rows // BLOCK_P,),
        # The whole padded ii is visible to each program; the kernel takes
        # the dynamic row slab it needs (overlapping reads — BlockSpec
        # cannot express halos directly).
        in_specs=[pl.BlockSpec((hp, wp), lambda i: (0, 0))],
        out_specs=[
            pl.BlockSpec((BLOCK_P, n_cols), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_P, n_cols), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, n_cols), jnp.float32),
            jax.ShapeDtypeStruct((n_rows, n_cols), jnp.float32),
        ],
        interpret=interpret,
    )(ii_padded)
    return score, mask
