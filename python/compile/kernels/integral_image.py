"""L1 Pallas kernel: integral image (summed-area table).

Two tiled passes: a row-scan kernel (each program owns a block of rows and
scans the full width) followed by a column-scan kernel (block of columns,
full height). Because each block spans the entire scanned axis there is no
cross-block carry, so the grid is embarrassingly parallel.

TPU mapping (DESIGN.md §Hardware-Adaptation): each pass streams HBM→VMEM one
row/column block at a time; the scan itself is a VPU op. Block heights are
chosen so a (BR, W) f32 tile stays well under VMEM (16 MB): for W=256,
BR=16 → 16 KB per tile. `interpret=True` everywhere — the CPU PJRT plugin
cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row/column block sizes. All supported image sides (32..256) are multiples.
BLOCK_ROWS = 16
BLOCK_COLS = 16


def _row_scan_kernel(x_ref, o_ref):
    # x_ref: (BLOCK_ROWS, W) — cumulative sum along the full row.
    o_ref[...] = jnp.cumsum(x_ref[...], axis=1)


def _col_scan_kernel(x_ref, o_ref):
    # x_ref: (H, BLOCK_COLS) — cumulative sum along the full column.
    o_ref[...] = jnp.cumsum(x_ref[...], axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def integral_image(x: jax.Array, interpret: bool = True) -> jax.Array:
    """Inclusive 2-D prefix sum of ``x`` (H, W) → (H, W), f32.

    The caller pads with a leading zero row/column to get the conventional
    exclusive summed-area table (see model.pad_integral).
    """
    h, w = x.shape
    assert h % BLOCK_ROWS == 0, f"height {h} not a multiple of {BLOCK_ROWS}"
    assert w % BLOCK_COLS == 0, f"width {w} not a multiple of {BLOCK_COLS}"
    x = x.astype(jnp.float32)

    rows = pl.pallas_call(
        _row_scan_kernel,
        grid=(h // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=interpret,
    )(x)

    cols = pl.pallas_call(
        _col_scan_kernel,
        grid=(w // BLOCK_COLS,),
        in_specs=[pl.BlockSpec((h, BLOCK_COLS), lambda j: (0, j))],
        out_specs=pl.BlockSpec((h, BLOCK_COLS), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=interpret,
    )(rows)

    return cols
