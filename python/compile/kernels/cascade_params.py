"""Procedurally generated Haar-like cascade parameters.

The paper runs OpenCV's trained Viola-Jones cascade inside its face-detection
container. A trained cascade file is proprietary-ish data we do not ship;
what the *system* needs is a compute graph with the same shape: multi-stage
box-feature evaluation over sliding windows on an integral image. We generate
a deterministic synthetic cascade (fixed seed) with the same structure
(stages of increasing feature count, per-feature weighted rectangle sums,
per-stage accept thresholds). DESIGN.md documents this substitution.

All parameters are plain Python ints/floats so they bake into the kernel
closure as constants and lower into the HLO (no runtime parameter traffic).
"""

from dataclasses import dataclass
from typing import List, Tuple

# Window side (paper's Viola-Jones uses 24; 16 keeps the smallest pyramid
# level (32 px) meaningful and all position counts multiples of 16).
WIN = 16

# Haar kinds: each is a list of (dx, dy, w, h, weight) sub-rectangles
# relative to the window origin, in *units of the feature cell*.
_KINDS = (
    "edge_h",   # 2-rect horizontal edge
    "edge_v",   # 2-rect vertical edge
    "line_h",   # 3-rect horizontal line
    "line_v",   # 3-rect vertical line
    "center",   # 4-rect center-surround (checker)
)


@dataclass(frozen=True)
class Rect:
    x: int
    y: int
    w: int
    h: int
    weight: float


@dataclass(frozen=True)
class Feature:
    rects: Tuple[Rect, ...]
    # post-sum shaping: score contribution = amp * tanh(v - shift)
    amp: float
    shift: float


@dataclass(frozen=True)
class Stage:
    features: Tuple[Feature, ...]
    threshold: float


class _SplitMix:
    """Tiny deterministic PRNG (SplitMix64) — mirrored by rust/src/util/rng.rs
    so both sides can generate identical synthetic data."""

    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.state = seed & self.MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & self.MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & self.MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & self.MASK
        return (z ^ (z >> 31)) & self.MASK

    def uniform(self) -> float:
        return self.next_u64() / float(1 << 64)

    def randint(self, lo: int, hi: int) -> int:
        """Inclusive range [lo, hi]."""
        return lo + self.next_u64() % (hi - lo + 1)

    def choice(self, seq):
        return seq[self.randint(0, len(seq) - 1)]


def _make_feature(rng: _SplitMix) -> Feature:
    kind = rng.choice(_KINDS)
    # Feature cell geometry, constrained inside the WIN x WIN window.
    if kind == "edge_h":
        cw = rng.randint(2, WIN // 2)
        ch = rng.randint(2, WIN - 1)
        x = rng.randint(0, WIN - 2 * cw)
        y = rng.randint(0, WIN - ch)
        rects = (Rect(x, y, cw, ch, +1.0), Rect(x + cw, y, cw, ch, -1.0))
    elif kind == "edge_v":
        cw = rng.randint(2, WIN - 1)
        ch = rng.randint(2, WIN // 2)
        x = rng.randint(0, WIN - cw)
        y = rng.randint(0, WIN - 2 * ch)
        rects = (Rect(x, y, cw, ch, +1.0), Rect(x, y + ch, cw, ch, -1.0))
    elif kind == "line_h":
        cw = rng.randint(2, WIN // 3)
        ch = rng.randint(2, WIN - 1)
        x = rng.randint(0, WIN - 3 * cw)
        y = rng.randint(0, WIN - ch)
        rects = (
            Rect(x, y, cw, ch, -1.0),
            Rect(x + cw, y, cw, ch, +2.0),
            Rect(x + 2 * cw, y, cw, ch, -1.0),
        )
    elif kind == "line_v":
        cw = rng.randint(2, WIN - 1)
        ch = rng.randint(2, WIN // 3)
        x = rng.randint(0, WIN - cw)
        y = rng.randint(0, WIN - 3 * ch)
        rects = (
            Rect(x, y, cw, ch, -1.0),
            Rect(x, y + ch, cw, ch, +2.0),
            Rect(x, y + 2 * ch, cw, ch, -1.0),
        )
    else:  # center-surround
        cw = rng.randint(2, WIN // 2 - 1)
        ch = rng.randint(2, WIN // 2 - 1)
        x = rng.randint(1, WIN - 2 * cw)
        y = rng.randint(1, WIN - 2 * ch)
        rects = (
            Rect(x - 1, y - 1, 2 * cw + 1, 2 * ch + 1, -1.0),
            Rect(x, y, cw * 2 - 1, ch * 2 - 1, +2.0),
        )
    amp = 0.5 + rng.uniform()          # in [0.5, 1.5)
    shift = (rng.uniform() - 0.5) * 0.2
    return Feature(rects=rects, amp=amp, shift=shift)


def _stage_scores_np(stage: Stage, windows) -> "np.ndarray":
    """Stage score for a batch of (K, WIN, WIN) windows — numpy, build-time
    calibration only."""
    import numpy as np

    k = windows.shape[0]
    # Zero-padded integral images, batched.
    s = np.cumsum(np.cumsum(windows.astype(np.float64), axis=1), axis=2)
    ii = np.pad(s, ((0, 0), (1, 0), (1, 0)))
    win_sum = ii[:, WIN, WIN]
    norm = win_sum / float(WIN * WIN) + 1.0
    score = np.zeros(k)
    for feat in stage.features:
        v = np.zeros(k)
        for r in feat.rects:
            v += r.weight * (
                ii[:, r.y + r.h, r.x + r.w]
                - ii[:, r.y, r.x + r.w]
                - ii[:, r.y + r.h, r.x]
                + ii[:, r.y, r.x]
            )
        v = v / (norm * float(WIN * WIN))
        score += feat.amp * np.tanh(v - feat.shift)
    return score


def make_cascade(
    seed: int = 7,
    feats_per_stage: Tuple[int, ...] = (2, 3, 5, 8, 10, 14),
    pass_rate: float = 0.5,
    calib_windows: int = 4096,
) -> Tuple[Stage, ...]:
    """Build the deterministic synthetic cascade.

    A trained cascade is tuned so each stage rejects a large fraction of
    non-faces. We reproduce that *shape* by calibrating every stage's
    threshold to the (1 - pass_rate) quantile of its score distribution on
    random noise windows: each stage passes ~pass_rate of random windows, so
    the 6-stage cascade passes ~pass_rate**6 — the early-reject funnel of
    Viola-Jones without trained weights. Fully deterministic (SplitMix seed).
    """
    import numpy as np

    rng = _SplitMix(seed)
    stages: List[Stage] = []
    for nf in feats_per_stage:
        feats = tuple(_make_feature(rng) for _ in range(nf))
        stages.append(Stage(features=feats, threshold=0.0))

    # Deterministic calibration noise (SplitMix-seeded numpy Philox).
    np_rng = np.random.Generator(np.random.Philox(rng.next_u64()))
    windows = np_rng.random((calib_windows, WIN, WIN))
    calibrated: List[Stage] = []
    for st in stages:
        scores = _stage_scores_np(st, windows)
        thr = float(np.quantile(scores, 1.0 - pass_rate))
        calibrated.append(Stage(features=st.features, threshold=thr))
    return tuple(calibrated)


def face_patch(scale: float = 2.0) -> "np.ndarray":
    """A canonical WIN×WIN patch that excites the cascade — the repo's
    stand-in for a face. Built by stamping each feature's positive rects
    bright and negative rects dark, so every stage scores far above its
    calibrated random-noise threshold.
    """
    import numpy as np

    patch = np.full((WIN, WIN), 0.5)
    for st in CASCADE:
        for feat in st.features:
            for r in feat.rects:
                delta = 0.5 if r.weight > 0 else -0.5
                patch[r.y : r.y + r.h, r.x : r.x + r.w] += delta * scale / len(CASCADE)
    return np.clip(patch, 0.0, 1.0)


#: The cascade every layer (kernel, ref oracle, tests, docs) shares.
CASCADE: Tuple[Stage, ...] = make_cascade()

#: Total feature count — used in FLOP estimates (DESIGN.md §Perf).
N_FEATURES: int = sum(len(s.features) for s in CASCADE)
