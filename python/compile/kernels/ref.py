"""Pure-jnp oracle for the Pallas kernels.

No Pallas, no tiling — direct whole-array formulations. pytest compares the
kernels against these with assert_allclose (the CORE correctness signal for
the compute layer).
"""

import jax
import jax.numpy as jnp

from .cascade_params import CASCADE, WIN


def integral_image_ref(x: jax.Array) -> jax.Array:
    """Inclusive 2-D prefix sum, whole-array."""
    return jnp.cumsum(jnp.cumsum(x.astype(jnp.float32), axis=0), axis=1)


def pad_integral_ref(s: jax.Array) -> jax.Array:
    """Inclusive table → conventional zero-padded summed-area table."""
    return jnp.pad(s, ((1, 0), (1, 0)))


def _box(ii, y, x, h, w, n_rows, n_cols):
    return (
        ii[y + h : y + h + n_rows, x + w : x + w + n_cols]
        - ii[y : y + n_rows, x + w : x + w + n_cols]
        - ii[y + h : y + h + n_rows, x : x + n_cols]
        + ii[y : y + n_rows, x : x + n_cols]
    )


def cascade_scores_ref(ii_padded: jax.Array):
    """Dense cascade over all window origins — same math as the kernel,
    but whole-array (no position blocking)."""
    hp, wp = ii_padded.shape
    n_rows, n_cols = (hp - 1) - WIN, (wp - 1) - WIN

    win_sum = _box(ii_padded, 0, 0, WIN, WIN, n_rows, n_cols)
    norm = win_sum / float(WIN * WIN) + 1.0

    alive = jnp.ones((n_rows, n_cols), dtype=jnp.float32)
    total = jnp.zeros((n_rows, n_cols), dtype=jnp.float32)
    for stage in CASCADE:
        score = jnp.zeros((n_rows, n_cols), dtype=jnp.float32)
        for feat in stage.features:
            v = jnp.zeros((n_rows, n_cols), dtype=jnp.float32)
            for r in feat.rects:
                v += r.weight * _box(ii_padded, r.y, r.x, r.h, r.w, n_rows, n_cols)
            v = v / (norm * float(WIN * WIN))
            score += feat.amp * jnp.tanh(v - feat.shift)
        alive = alive * (score > stage.threshold).astype(jnp.float32)
        total = total + alive * score
    return total, alive
