"""L1 Haar-cascade kernel vs pure-jnp oracle + cascade invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.cascade_params import (
    CASCADE,
    N_FEATURES,
    WIN,
    face_patch,
    make_cascade,
)
from compile.kernels.haar_cascade import cascade_scores


def _padded_ii(img):
    return ref.pad_integral_ref(ref.integral_image_ref(jnp.asarray(img, jnp.float32)))


@settings(max_examples=15, deadline=None)
@given(side=st.sampled_from([32, 48, 64, 96]), seed=st.integers(0, 2**31 - 1))
def test_matches_ref_random(side, seed):
    img = np.random.RandomState(seed).rand(side, side)
    ii = _padded_ii(img)
    s_k, m_k = cascade_scores(ii)
    s_r, m_r = ref.cascade_scores_ref(ii)
    np.testing.assert_allclose(s_k, s_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(m_k, m_r)
    assert s_k.shape == (side - WIN, side - WIN)


def test_mask_binary_and_score_consistency():
    img = np.random.RandomState(5).rand(64, 64)
    s, m = cascade_scores(_padded_ii(img))
    m = np.asarray(m)
    s = np.asarray(s)
    assert set(np.unique(m)) <= {0.0, 1.0}
    # Non-survivors accumulate no score after their rejecting stage; any
    # window with mask=1 must have positive total (every stage it passed
    # contributed a score above a calibrated threshold >= stage minimum).
    assert (s[m == 1.0] > 0.0).all()


def test_noise_rejection_rate():
    """Calibrated cascade rejects the vast majority of random windows."""
    img = np.random.RandomState(6).rand(128, 128)
    _, m = cascade_scores(_padded_ii(img))
    rate = float(np.asarray(m).mean())
    assert rate < 0.25, f"noise survival rate {rate} too high"


def test_face_patch_detected():
    """The canonical face patch passes all stages at its plant position."""
    img = np.random.RandomState(7).rand(64, 64) * 0.2
    y0, x0 = 12, 24
    img[y0 : y0 + WIN, x0 : x0 + WIN] = face_patch()
    s, m = cascade_scores(_padded_ii(img))
    assert float(np.asarray(m)[y0, x0]) == 1.0
    # And it is the strongest response in the image.
    am = np.unravel_index(np.argmax(np.asarray(s)), s.shape)
    assert abs(am[0] - y0) <= 2 and abs(am[1] - x0) <= 2


def test_survivors_monotone_in_stages():
    """Each additional stage can only shrink the survivor set."""
    img = np.random.RandomState(8).rand(64, 64)
    ii = _padded_ii(img)
    prev = None
    for n_stages in range(1, len(CASCADE) + 1):
        sub = CASCADE[:n_stages]
        # Re-run the ref cascade truncated to n stages.
        import compile.kernels.ref as _r

        orig = _r.CASCADE
        try:
            _r.CASCADE = sub
            _, m = _r.cascade_scores_ref(ii)
        finally:
            _r.CASCADE = orig
        cur = set(map(tuple, np.argwhere(np.asarray(m) > 0)))
        if prev is not None:
            assert cur <= prev
        prev = cur


def test_cascade_determinism():
    """make_cascade is a pure function of its seed."""
    a = make_cascade(seed=7)
    b = make_cascade(seed=7)
    c = make_cascade(seed=8)
    assert a == b
    assert a != c
    assert N_FEATURES == sum(len(s.features) for s in a)


def test_rect_geometry_in_window():
    """All feature rectangles lie inside the WIN x WIN window."""
    for stage in CASCADE:
        for feat in stage.features:
            for r in feat.rects:
                assert 0 <= r.x and r.x + r.w <= WIN
                assert 0 <= r.y and r.y + r.h <= WIN
                assert r.w >= 1 and r.h >= 1


def test_brightness_invariance_direction():
    """Uniform brightness offset barely moves scores (normalization)."""
    img = np.random.RandomState(9).rand(48, 48) * 0.5
    s1, _ = cascade_scores(_padded_ii(img))
    s2, _ = cascade_scores(_padded_ii(img + 0.3))
    # Not exactly invariant (mean-energy normalization), but close.
    assert float(np.abs(np.asarray(s1) - np.asarray(s2)).mean()) < 0.5
