"""Property-based tests over the full L2 pipeline (hypothesis)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.cascade_params import WIN


def _img(side, seed, lo=0.0, hi=1.0):
    r = np.random.RandomState(seed).rand(side, side, 3)
    return jnp.asarray(lo + (hi - lo) * r, jnp.float32)


@settings(max_examples=8, deadline=None)
@given(side=st.sampled_from([32, 64, 128]), seed=st.integers(0, 2**31 - 1))
def test_counts_bounded_by_window_grid(side, seed):
    """Survivor count can never exceed the number of evaluated windows."""
    counts, max_score, hist = model.detect(_img(side, seed))
    total_windows = sum(
        (side // (2**l) - WIN) ** 2 for l in range(model.n_levels(side))
    )
    assert 0 <= float(np.asarray(counts).sum()) <= total_windows
    assert float(max_score) >= 0.0
    assert (np.asarray(hist) >= 0).all()


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.3, 1.0))
def test_contrast_scaling_keeps_outputs_finite(seed, scale):
    """Arbitrary contrast compression never produces NaN/inf anywhere."""
    counts, max_score, hist = model.detect(_img(64, seed, hi=scale))
    for out in (counts, max_score, hist):
        assert np.isfinite(np.asarray(out)).all()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_channel_permutation_changes_little_on_gray_content(seed):
    """For a grayscale image (equal channels), channel order is irrelevant."""
    r = np.random.RandomState(seed).rand(64, 64, 1)
    img = np.repeat(r, 3, axis=2)
    a = model.detect(jnp.asarray(img, jnp.float32))
    b = model.detect(jnp.asarray(img[..., ::-1].copy(), jnp.float32))
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-5)


def test_black_and_white_images_have_no_detections():
    """Featureless images excite nothing (calibrated thresholds > flat
    response)."""
    for value in (0.0, 1.0):
        img = jnp.full((64, 64, 3), value, jnp.float32)
        counts, _, _ = model.detect(img)
        assert float(np.asarray(counts).sum()) == 0.0, f"value={value}"
