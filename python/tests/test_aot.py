"""AOT path: HLO text is produced, parseable-looking, and manifest-complete."""

import json
import os

import pytest

from compile import aot, model


def test_lower_64_produces_hlo_text():
    text = aot.lower_size(64)
    assert "ENTRY" in text and "HloModule" in text
    # Tuple return (return_tuple=True) — rust unwraps a 3-tuple.
    assert "tuple(" in text.lower() or "(f32[4]" in text


def test_lowered_io_shapes():
    text = aot.lower_size(64)
    # Input: 64x64x3 f32; outputs: f32[4], f32[], f32[16].
    assert "f32[64,64,3]" in text
    assert "f32[4]" in text
    assert "f32[16]" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_manifest_matches_artifacts():
    adir = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(adir, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["model"] == "haar-face-detect"
    for entry in manifest["entries"]:
        path = os.path.join(adir, entry["file"])
        assert os.path.exists(path), entry["file"]
        assert os.path.getsize(path) == entry["bytes"]
        assert entry["levels"] == model.n_levels(entry["side"])
        assert entry["outputs"][0]["shape"] == [model.MAX_LEVELS]
        assert entry["outputs"][2]["shape"] == [model.N_BINS]
