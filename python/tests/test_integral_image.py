"""L1 integral-image kernel vs pure-jnp oracle (hypothesis shape sweep)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.integral_image import BLOCK_COLS, BLOCK_ROWS, integral_image
from compile.kernels.ref import integral_image_ref, pad_integral_ref

# Sides must be multiples of the block sizes (model guarantees this).
SIDES = st.sampled_from([16, 32, 48, 64, 96, 128])


@settings(max_examples=20, deadline=None)
@given(h=SIDES, w=SIDES, seed=st.integers(0, 2**31 - 1))
def test_matches_ref_random(h, w, seed):
    x = jnp.array(np.random.RandomState(seed).rand(h, w), jnp.float32)
    got = integral_image(x)
    want = integral_image_ref(x)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    h=SIDES,
    w=SIDES,
    dtype=st.sampled_from([np.float32, np.float64, np.int32, np.uint8]),
)
def test_dtype_sweep(h, w, dtype):
    """Kernel accepts any numeric dtype and produces f32."""
    x = (np.random.RandomState(0).rand(h, w) * 10).astype(dtype)
    got = integral_image(jnp.asarray(x))
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(
        got, integral_image_ref(jnp.asarray(x)), rtol=3e-5, atol=1e-3
    )


def test_constant_image():
    """ii[i,j] of all-ones = (i+1)*(j+1)."""
    x = jnp.ones((32, 32), jnp.float32)
    got = np.asarray(integral_image(x))
    i, j = np.meshgrid(np.arange(32), np.arange(32), indexing="ij")
    np.testing.assert_allclose(got, (i + 1.0) * (j + 1.0), rtol=1e-6)


def test_monotone_rows_cols():
    """Prefix sums of nonnegative input are monotone along both axes."""
    x = jnp.array(np.random.RandomState(3).rand(48, 64), jnp.float32)
    s = np.asarray(integral_image(x))
    assert (np.diff(s, axis=0) >= -1e-6).all()
    assert (np.diff(s, axis=1) >= -1e-6).all()


def test_pad_integral():
    x = jnp.array(np.random.RandomState(4).rand(32, 32), jnp.float32)
    ii = np.asarray(pad_integral_ref(integral_image_ref(x)))
    assert ii.shape == (33, 33)
    assert (ii[0, :] == 0).all() and (ii[:, 0] == 0).all()
    # Box-sum identity: sum of any rect equals direct sum.
    xs = np.asarray(x)
    for (y, x0, h, w) in [(0, 0, 5, 7), (3, 9, 11, 2), (20, 20, 12, 12)]:
        box = ii[y + h, x0 + w] - ii[y, x0 + w] - ii[y + h, x0] + ii[y, x0]
        np.testing.assert_allclose(box, xs[y : y + h, x0 : x0 + w].sum(), rtol=1e-5)


def test_rejects_unaligned_shape():
    with pytest.raises(AssertionError):
        integral_image(jnp.ones((17, 32), jnp.float32))
    assert BLOCK_ROWS == BLOCK_COLS == 16  # documented invariant
