"""L2 pipeline: shapes, determinism, pyramid behaviour, detection signal."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.cascade_params import WIN, face_patch


def _img(side, seed=0, lo=0.0, hi=1.0):
    r = np.random.RandomState(seed).rand(side, side, 3)
    return jnp.asarray(lo + (hi - lo) * r, jnp.float32)


@pytest.mark.parametrize("side,levels", [(32, 1), (64, 2), (128, 3), (256, 4)])
def test_shapes_and_levels(side, levels):
    counts, max_score, hist = model.detect(_img(side))
    assert counts.shape == (model.MAX_LEVELS,)
    assert max_score.shape == ()
    assert hist.shape == (model.N_BINS,)
    assert model.n_levels(side) == levels
    # Unused levels stay zero.
    assert (np.asarray(counts)[levels:] == 0).all()


def test_deterministic():
    a = model.detect(_img(64, seed=1))
    b = model.detect(_img(64, seed=1))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_grayscale_weights():
    img = jnp.ones((8, 8, 3), jnp.float32)
    np.testing.assert_allclose(np.asarray(model.grayscale(img)), 1.0, rtol=1e-6)
    red = jnp.zeros((8, 8, 3), jnp.float32).at[..., 0].set(1.0)
    np.testing.assert_allclose(np.asarray(model.grayscale(red)), 0.299, rtol=1e-5)


def test_downsample2():
    x = jnp.arange(16, dtype=jnp.float32).reshape(4, 4)
    d = np.asarray(model.downsample2(x))
    assert d.shape == (2, 2)
    np.testing.assert_allclose(d[0, 0], (0 + 1 + 4 + 5) / 4)


def test_hist_counts_match():
    """Histogram total equals total survivor count across levels."""
    counts, _, hist = model.detect(_img(128, seed=3))
    np.testing.assert_allclose(
        float(np.asarray(counts).sum()), float(np.asarray(hist).sum()), rtol=1e-5
    )


def test_face_increases_response():
    """Planting the canonical face raises max_score vs the same image
    without it."""
    base = np.random.RandomState(11).rand(64, 64, 3) * 0.2
    _, ms_plain, _ = model.detect(jnp.asarray(base, jnp.float32))
    with_face = base.copy()
    with_face[8 : 8 + WIN, 8 : 8 + WIN, :] = face_patch()[..., None]
    _, ms_face, _ = model.detect(jnp.asarray(with_face, jnp.float32))
    assert float(ms_face) > float(ms_plain)


def test_compute_scales_with_size():
    """Bigger images evaluate more windows — the paper's Table II driver.
    (Verified structurally: number of window positions grows ~4x per side
    doubling; see rust benches for the timing reproduction.)"""
    positions = {s: sum((s // (2**l) - WIN) ** 2 for l in range(model.n_levels(s)))
                 for s in (64, 128, 256)}
    assert positions[128] > 3 * positions[64]
    assert positions[256] > 3 * positions[128]
